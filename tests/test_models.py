"""Model-zoo correctness: attention/SSD/MoE oracles + arch smoke tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import Batch, build_model
from repro.models.attention import blockwise_attention, decode_attention, naive_attention
from repro.models.moe import moe_ffn
from repro.models.ssm import causal_conv, conv_step, ssd_chunked, ssd_decode_step


# ------------------------------------------------------------------ attention

class TestAttention:
    @pytest.mark.parametrize("causal,window,prefix", [
        (True, 0, 0), (True, 16, 0), (False, 0, 0), (True, 0, 8),
    ])
    @pytest.mark.parametrize("nkv", [1, 2, 4])
    def test_blockwise_matches_naive(self, causal, window, prefix, nkv):
        rng = np.random.default_rng(0)
        b, s, nh, hd = 2, 64, 4, 16
        q = jnp.asarray(rng.standard_normal((b, s, nh, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, nkv, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, nkv, hd)), jnp.float32)
        kw = dict(scale=hd ** -0.5, causal=causal, window=window, prefix_len=prefix)
        out_b = blockwise_attention(q, k, v, q_block=16, kv_block=16, **kw)
        out_n = naive_attention(q, k, v, **kw)
        np.testing.assert_allclose(out_b, out_n, rtol=2e-5, atol=2e-5)

    def test_softcap_matches(self):
        rng = np.random.default_rng(1)
        b, s, nh, hd = 1, 32, 2, 8
        q = jnp.asarray(rng.standard_normal((b, s, nh, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, nh, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, nh, hd)), jnp.float32)
        kw = dict(scale=hd ** -0.5, causal=True, logit_softcap=5.0)
        out_b = blockwise_attention(q, k, v, q_block=8, kv_block=8, **kw)
        out_n = naive_attention(q, k, v, **kw)
        np.testing.assert_allclose(out_b, out_n, rtol=2e-5, atol=2e-5)

    def test_decode_matches_last_row(self):
        rng = np.random.default_rng(2)
        b, s, nh, nkv, hd = 2, 32, 4, 2, 16
        q = jnp.asarray(rng.standard_normal((b, s, nh, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, nkv, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, nkv, hd)), jnp.float32)
        full = naive_attention(q, k, v, scale=hd ** -0.5, causal=True)
        # decode the last position against a cache padded to 48
        S = 48
        kc = jnp.pad(k, ((0, 0), (0, S - s), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, S - s), (0, 0), (0, 0)))
        out = decode_attention(q[:, -1:], kc, vc, jnp.asarray(s - 1),
                               scale=hd ** -0.5)
        np.testing.assert_allclose(out[:, 0], full[:, -1], rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------- SSD

def ssd_sequential_oracle(x, dt, A, B, C, D):
    """Token-by-token state recurrence (the definition)."""
    b, l, nh, hd = x.shape
    ds = B.shape[-1]
    state = np.zeros((b, nh, hd, ds), np.float64)
    ys = np.zeros((b, l, nh, hd), np.float64)
    x64, dt64, B64, C64 = map(lambda a: np.asarray(a, np.float64), (x, dt, B, C))
    A64, D64 = np.asarray(A, np.float64), np.asarray(D, np.float64)
    for t in range(l):
        da = np.exp(dt64[:, t] * A64)  # (b, nh)
        upd = np.einsum("bnp,bs,bn->bnps", x64[:, t], B64[:, t], dt64[:, t])
        state = state * da[:, :, None, None] + upd
        ys[:, t] = np.einsum("bnps,bs->bnp", state, C64[:, t]) + D64[None, :, None] * x64[:, t]
    return ys, state


class TestSSD:
    @pytest.mark.parametrize("l,chunk", [(32, 8), (64, 16), (64, 64), (48, 16)])
    def test_chunked_matches_sequential(self, l, chunk):
        rng = np.random.default_rng(3)
        b, nh, hd, ds = 2, 4, 8, 16
        x = jnp.asarray(rng.standard_normal((b, l, nh, hd)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, l, nh)), jnp.float32)
        A = -jnp.asarray(rng.uniform(0.5, 2.0, (nh,)), jnp.float32)
        B = jnp.asarray(rng.standard_normal((b, l, ds)), jnp.float32)
        C = jnp.asarray(rng.standard_normal((b, l, ds)), jnp.float32)
        D = jnp.asarray(rng.standard_normal((nh,)), jnp.float32)
        y, st = ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
        y_ref, st_ref = ssd_sequential_oracle(x, dt, A, B, C, D)
        np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(st, st_ref, rtol=1e-4, atol=1e-4)

    def test_decode_continues_prefill(self):
        rng = np.random.default_rng(4)
        b, l, nh, hd, ds = 2, 32, 4, 8, 16
        p = 24  # prefill length (divisible by chunk)
        mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
        x, B, C = mk(b, l, nh, hd), mk(b, l, ds), mk(b, l, ds)
        dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, l, nh)), jnp.float32)
        A = -jnp.asarray(rng.uniform(0.5, 2.0, (nh,)), jnp.float32)
        D = mk(nh)
        y_full, _ = ssd_chunked(x, dt, A, B, C, D, chunk=8)
        _, st = ssd_chunked(x[:, :p], dt[:, :p], A, B[:, :p], C[:, :p], D, chunk=8)
        y_t, _ = ssd_decode_step(st, x[:, p], dt[:, p], A, B[:, p], C[:, p], D)
        np.testing.assert_allclose(y_t, y_full[:, p], rtol=1e-4, atol=1e-4)

    def test_conv_step_matches(self):
        rng = np.random.default_rng(5)
        b, l, ch, w = 2, 16, 6, 4
        x = jnp.asarray(rng.standard_normal((b, l, ch)), jnp.float32)
        wt = jnp.asarray(rng.standard_normal((w, ch)), jnp.float32)
        bias = jnp.asarray(rng.standard_normal((ch,)), jnp.float32)
        y = causal_conv(x, wt, bias)
        y_t, _ = conv_step(x[:, l - w : l - 1, :], x[:, l - 1], wt, bias)
        np.testing.assert_allclose(y_t, y[:, -1], rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------- MoE

class TestMoE:
    def test_ample_capacity_matches_dense(self):
        """With capacity ≥ tokens, index dispatch must equal the dense
        (every-expert) computation weighted by the router."""
        from repro.models.config import ModelConfig
        cfg = ModelConfig(
            name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
            num_kv_heads=2, d_ff=32, vocab_size=64, num_experts=4,
            num_experts_per_tok=2, moe_d_ff=32, capacity_factor=64.0,
        )
        rng = np.random.default_rng(6)
        t, D, E, F = 8, 16, 4, 32
        params = {
            "router": jnp.asarray(rng.standard_normal((D, E)), jnp.float32),
            "w_in": jnp.asarray(rng.standard_normal((E, D, F)) * 0.1, jnp.float32),
            "w_gate": jnp.asarray(rng.standard_normal((E, D, F)) * 0.1, jnp.float32),
            "w_out": jnp.asarray(rng.standard_normal((E, F, D)) * 0.1, jnp.float32),
        }
        x = jnp.asarray(rng.standard_normal((1, t, D)), jnp.float32)
        y, aux = moe_ffn(params, x, cfg)
        # dense oracle
        logits = np.asarray(x[0] @ params["router"])
        probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
        top = np.argsort(-probs, axis=-1)[:, :2]
        y_ref = np.zeros((t, D), np.float32)
        for i in range(t):
            g = probs[i, top[i]]
            g = g / g.sum()
            for j, e in enumerate(top[i]):
                h = np.asarray(x[0, i] @ params["w_in"][e])
                gt = np.asarray(x[0, i] @ params["w_gate"][e])
                silu = gt / (1 + np.exp(-gt))
                y_ref[i] += g[j] * (silu * h) @ np.asarray(params["w_out"][e])
        np.testing.assert_allclose(y[0], y_ref, rtol=1e-4, atol=1e-4)
        assert np.isfinite(float(aux))

    def test_capacity_drops_tokens(self):
        from repro.models.config import ModelConfig
        cfg = ModelConfig(
            name="t", family="moe", num_layers=1, d_model=8, num_heads=2,
            num_kv_heads=2, d_ff=16, vocab_size=64, num_experts=2,
            num_experts_per_tok=1, moe_d_ff=16, capacity_factor=0.25,
        )
        rng = np.random.default_rng(7)
        params = {
            "router": jnp.asarray(rng.standard_normal((8, 2)), jnp.float32),
            "w_in": jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32),
            "w_gate": jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32),
            "w_out": jnp.asarray(rng.standard_normal((2, 16, 8)), jnp.float32),
        }
        x = jnp.asarray(rng.standard_normal((1, 16, 8)), jnp.float32)
        y, _ = moe_ffn(params, x, cfg)
        # some token outputs must be exactly zero (dropped)
        zero_rows = np.sum(np.all(np.asarray(y[0]) == 0.0, axis=-1))
        assert zero_rows > 0


# ------------------------------------------------------------- arch smoke

@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def _batch(self, cfg, b=2, s=32):
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        pe = None
        if cfg.is_encoder_decoder or cfg.num_prefix_tokens:
            p = cfg.num_prefix_tokens or 16
            pe = jnp.asarray(rng.standard_normal((b, p, cfg.d_model)) * 0.02,
                             jnp.float32)
        return Batch(tokens=tokens, labels=tokens, prefix_embeds=pe)

    def test_forward_and_grad_step(self, arch):
        cfg = reduced(get_config(arch))
        m = build_model(cfg)
        params = m.init(0)
        batch = self._batch(cfg)
        loss, grads = jax.value_and_grad(lambda p: m.loss(p, batch))(params)
        assert np.isfinite(float(loss))
        gnorm = jax.tree.reduce(
            lambda a, g: a + float(jnp.sum(jnp.square(g))), grads, 0.0
        )
        assert np.isfinite(gnorm) and gnorm > 0
        # logits shape
        logits = m.logits(params, batch)
        assert logits.shape == (2, 32, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_prefill_decode_matches_forward(self, arch):
        cfg = reduced(get_config(arch))
        m = build_model(cfg)
        params = m.init(0)
        b, s = 2, 32
        batch = self._batch(cfg, b, s)
        cache_len = 48
        # teacher-forced logits for the full sequence
        full = m.logits(params, batch)
        logits_p, cache = m.prefill(params, Batch(tokens=batch.tokens[:, : s - 1],
                                                  prefix_embeds=batch.prefix_embeds),
                                    cache_len=cache_len)
        np.testing.assert_allclose(
            np.asarray(logits_p[:, 0]), np.asarray(full[:, s - 2]),
            rtol=2e-3, atol=2e-3,
        )
        # one decode step must match the teacher-forced next-position logits
        prefix = cfg.num_prefix_tokens if (cfg.num_prefix_tokens and not cfg.is_encoder_decoder) else 0
        pos = jnp.asarray(s - 1 + prefix, jnp.int32)
        logits_d, _ = m.decode_step(params, cache, batch.tokens[:, s - 1], pos)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full[:, s - 1]), rtol=2e-3, atol=2e-3,
        )
