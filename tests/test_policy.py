"""Warm-pool policy tests: eviction order per policy, honest budget
accounting (including the 2x charge for device-patched instances), put()
rejection surfacing, and Strategy.AUTO's planner-driven selection."""

import numpy as np
import pytest

from repro.core.planner import PAPER_C220G5, SnapshotSizes, predict
from repro.serving import (
    GDSFPolicy,
    InstancePool,
    LRUPolicy,
    Strategy,
    TTLPolicy,
    select_strategy,
)


# ------------------------------------------------------------- pool + policies

class TestLRU:
    def test_eviction_order_is_recency(self):
        pool = InstancePool(100, policy=LRUPolicy())
        assert pool.put("a", "A", 40)
        assert pool.put("b", "B", 40)
        assert pool.get("a") == "A"        # refresh a
        assert pool.put("c", "C", 40)      # must evict b (LRU), not a
        assert pool.get("b") is None
        assert pool.get("a") == "A"
        assert pool.get("c") == "C"

    def test_budget_accounting(self):
        pool = InstancePool(100, policy=LRUPolicy())
        pool.put("a", "A", 60)
        pool.put("b", "B", 30)
        assert pool.used == 90
        pool.drop("a")
        assert pool.used == 30
        pool.put("b", "B2", 50)            # re-put refreshes size
        assert pool.used == 50 and len(pool) == 1

    def test_put_rejects_oversize_and_counts(self):
        """Seed bug: an instance larger than the whole budget evicted
        everything, then silently vanished.  Now the caller learns."""
        pool = InstancePool(100, policy=LRUPolicy())
        assert pool.put("a", "A", 60)
        assert not pool.put("big", "B", 150)
        assert pool.rejections == 1
        assert pool.get("a") == "A"        # small entry not collateral damage
        assert pool.used == 60

    def test_stats_and_hit_rate(self):
        pool = InstancePool(100)
        pool.put("a", "A", 10)
        pool.get("a"); pool.get("a"); pool.get("zzz")
        s = pool.stats()
        assert s["hits"] == 2 and s["misses"] == 1
        assert s["warm_hit_rate"] == pytest.approx(2 / 3, abs=1e-4)


class TestGDSF:
    def test_keeps_expensive_frequent_over_recent_cheap(self):
        pool = InstancePool(100, policy=GDSFPolicy())
        # "hot" is popular and expensive to re-boot; "scan" is a one-touch
        # cheap function that arrives later (more recent — LRU would keep it)
        pool.put("hot", "H", 50, cost=1.0)
        for _ in range(5):
            assert pool.get("hot") == "H"
        pool.put("scan1", "S1", 50, cost=0.001)
        assert pool.put("scan2", "S2", 50, cost=0.001)  # evicts scan1, not hot
        assert pool.get("hot") == "H"
        assert pool.get("scan1") is None

    def test_clock_aging_lets_new_entries_compete(self):
        p = GDSFPolicy()
        p.on_admit("old", 1 << 20, 10.0)
        p.on_evict("old")
        # after eviction the clock rose to old's H; a new cheap entry's
        # priority builds on the clock, so it isn't instantly the victim
        # against hypothetical stale entries
        assert p.clock > 0

    def test_clock_only_raised_by_true_eviction(self):
        """Warm-hit re-puts and explicit drops must not age the clock, or
        GDSF degenerates to recency ordering (every warm hit would raise
        the global floor past older entries' priorities)."""
        pool = InstancePool(100, policy=GDSFPolicy())
        pool.put("hot", "H", 50, cost=1.0)
        for _ in range(3):
            assert pool.get("hot") == "H"
            pool.put("hot", "H", 50, cost=1.0)   # refresh re-put
        assert pool.policy.clock == 0.0
        pool.drop("hot")
        assert pool.policy.clock == 0.0
        pool.put("a", "A", 60, cost=0.5)
        pool.put("b", "B", 60, cost=0.5)         # evicts a → clock = H(a)
        assert pool.policy.clock > 0.0

    def test_refresh_reput_does_not_inflate_frequency(self):
        """Worker.invoke re-puts the instance after every request; that
        accounting refresh must not count as an access, or freq tracks pool
        mechanics instead of invocations (H inflated ~2x for warm-served
        functions)."""
        policy = GDSFPolicy()
        pool = InstancePool(100, policy=policy)
        pool.put("a", "A", 10, cost=0.5)           # cold: admit (+1)
        assert pool.get("a") == "A"                # warm hit (+1)
        pool.put("a", "A", 10, cost=0.5)           # end-of-request refresh
        assert policy._freq["a"] == 2

    def test_eviction_order_is_min_priority(self):
        pool = InstancePool(90, policy=GDSFPolicy())
        pool.put("cheap", "c", 30, cost=0.01)
        pool.put("mid", "m", 30, cost=0.1)
        pool.put("dear", "d", 30, cost=1.0)
        pool.put("new", "n", 30, cost=0.5)   # evicts "cheap" (lowest H)
        assert pool.get("cheap") is None
        assert pool.get("dear") == "d"


class TestTTL:
    def test_expiry_drops_entry(self):
        now = [0.0]
        pool = InstancePool(100, policy=TTLPolicy(ttl_s=10.0, clock=lambda: now[0]))
        pool.put("a", "A", 10)
        now[0] = 5.0
        assert pool.get("a") == "A"        # touch refreshes the grace window
        now[0] = 14.0
        assert pool.get("a") == "A"        # 5 + 10 > 14; refreshes to 24
        now[0] = 25.0
        assert pool.get("a") is None       # expired
        assert pool.used == 0

    def test_eviction_order_is_earliest_expiry(self):
        now = [0.0]
        pool = InstancePool(100, policy=TTLPolicy(ttl_s=10.0, clock=lambda: now[0]))
        pool.put("a", "A", 40)
        now[0] = 1.0
        pool.put("b", "B", 40)
        now[0] = 2.0
        pool.put("c", "C", 40)             # evicts a (earliest deadline)
        assert pool.get("a") is None
        assert pool.get("b") == "B"


# --------------------------------------------------- device-copy (2x) charge

class TestPoolChargesDeviceCopies:
    @pytest.fixture(scope="class")
    def worker_and_specs(self, tmp_path_factory):
        from repro.configs import get_config, reduced
        from repro.models import build_model
        from repro.serving.trace import build_functions
        root = str(tmp_path_factory.mktemp("poolcharge"))
        cfg = reduced(get_config("gemma-2b"))
        model = build_model(cfg)
        return build_functions(root, cfg, model, n_functions=3), cfg

    def test_patched_instance_charged_twice(self, worker_and_specs):
        """A warm instance whose arrays were patched on device pins a
        full-size accelerator copy (ma._dev) on top of the host buffers —
        the pool must charge both (Fig. 7 residency honesty)."""
        from repro.serving import ColdStartOptions, InvocationRequest
        from repro.serving.trace import request_tokens
        (worker, specs), cfg = worker_and_specs
        spec = specs[1]  # head: full-table diff → device-patchable arrays
        toks = request_tokens(spec, np.random.default_rng(0), cfg.vocab_size)
        r = worker.invoke(InvocationRequest(
            function=spec.name, tokens=toks,
            options=ColdStartOptions(strategy=Strategy.SNAPFAAS,
                                     force_cold=True),
        ))
        assert r.pooled
        charged = worker.pool.size_of(spec.name)
        inst = worker.pool.get(spec.name)
        expected = sum(
            a.meta.nbytes * (2 if a._dev is not None else 1)
            for a in inst.arrays.values()
        )
        assert charged == expected
        assert any(a._dev is not None for a in inst.arrays.values()), \
            "test premise broken: no array was device-patched"
        assert charged > sum(a.meta.nbytes for a in inst.arrays.values())


# ----------------------------------------------------------- Strategy.AUTO

def _sizes(**kw) -> SnapshotSizes:
    base = dict(
        full_bytes=0, diff_bytes=0, ws_bytes=0, ws_full_bytes=0, ws_chunks=0,
        non_ws_diff_bytes=0, non_ws_diff_chunks=0, shared_bytes=0,
        cow_bytes=0, cow_faults=0, init_compute=0.0, residual_init=0.0,
    )
    base.update(kw)
    return SnapshotSizes(**base)


class TestAutoStrategy:
    hw = PAPER_C220G5

    def _check_argmin(self, sizes):
        best, preds = select_strategy(sizes, self.hw)
        want = min(preds.values(), key=lambda p: p.total).total
        assert preds[best].total == pytest.approx(want)
        return best

    def test_small_ws_picks_snapfaas(self):
        s = _sizes(full_bytes=200 << 20, diff_bytes=100 << 20,
                   ws_bytes=1 << 20, ws_full_bytes=150 << 20,
                   init_compute=1.0)
        assert self._check_argmin(s) is Strategy.SNAPFAAS

    def test_tiny_init_huge_diff_picks_seuss(self):
        s = _sizes(full_bytes=500 << 20, diff_bytes=400 << 20,
                   ws_bytes=100 << 20, ws_full_bytes=400 << 20,
                   init_compute=0.001, cow_bytes=1 << 20, cow_faults=16)
        assert self._check_argmin(s) is Strategy.SEUSS

    def test_huge_cow_and_demand_picks_regular(self):
        # CoW + demand misses kill every sharing strategy; reading the full
        # image sequentially is cheapest
        s = _sizes(full_bytes=50 << 20, diff_bytes=45 << 20,
                   ws_bytes=40 << 20, ws_full_bytes=50 << 20,
                   init_compute=0.0,
                   cow_bytes=10 << 30, cow_faults=1 << 16,
                   exec_demand_miss_bytes=10 << 30,
                   exec_demand_miss_chunks=1 << 16)
        assert self._check_argmin(s) is Strategy.REGULAR

    def test_prediction_matches_planner(self):
        s = _sizes(full_bytes=64 << 20, diff_bytes=8 << 20, ws_bytes=1 << 20,
                   ws_full_bytes=32 << 20, init_compute=0.5)
        _, preds = select_strategy(s, self.hw)
        for strat, pred in preds.items():
            ref = predict(strat.value, s, self.hw)
            assert pred.total == pytest.approx(ref.total)

    def test_worker_resolves_auto_via_planner(self, tmp_path, monkeypatch):
        """Worker.resolve_strategy(fn, AUTO) returns select_strategy's argmin
        over the registry's measured sizes."""
        from repro.configs import get_config, reduced
        from repro.models import build_model
        from repro.serving.trace import build_functions
        cfg = reduced(get_config("gemma-2b"))
        model = build_model(cfg)
        worker, specs = build_functions(str(tmp_path), cfg, model,
                                        n_functions=1)
        fn = specs[0].name
        synthetic = _sizes(full_bytes=500 << 20, diff_bytes=400 << 20,
                           ws_bytes=100 << 20, ws_full_bytes=400 << 20,
                           init_compute=0.001)
        monkeypatch.setattr(worker.registry, "sizes", lambda name: synthetic)
        worker._auto.clear()
        assert worker.resolve_strategy(fn, Strategy.AUTO) is Strategy.SEUSS
        assert worker.resolve_strategy(fn, "snapfaas") is Strategy.SNAPFAAS
        # cost hook: predicted re-cold-start latency comes from the same table
        cost = worker.predicted_cost(fn, Strategy.SEUSS)
        assert cost == pytest.approx(
            predict("seuss", synthetic, worker.storage).total)

    def test_auto_cache_invalidated_by_ws_regeneration(self, tmp_path):
        """Regenerating a function's working set through the registry (which
        clears its restore plans) must also invalidate the worker's cached
        AUTO resolution."""
        from repro.configs import get_config, reduced
        from repro.core import AccessLog
        from repro.models import build_model
        from repro.serving.trace import build_functions
        cfg = reduced(get_config("gemma-2b"))
        model = build_model(cfg)
        worker, specs = build_functions(str(tmp_path), cfg, model,
                                        n_functions=1)
        fn = specs[0].name
        before = worker._auto_entry(fn)
        log = AccessLog()
        for path in specs[0].variant:
            log.touch(path)
        worker.registry.generate_working_set(fn, log)   # new ws object
        after = worker._auto_entry(fn)
        assert after[0] is worker.registry.functions[fn].ws
        assert after[0] is not before[0]                # cache rebuilt

    def test_coerce_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown strategy"):
            Strategy.coerce("warmish")
        assert Strategy.coerce("snapfaas-") is Strategy.SNAPFAAS_MINUS
        assert Strategy.coerce(Strategy.AUTO) is Strategy.AUTO
