"""Regression tests for the concurrency bugs the soak harness shook out:
the plan-cache epoch check-then-act race in ``ZygoteRegistry``, the tier
lookup-then-read windows against concurrent demotion, and the RAM tier's
formerly-silent residency mutations."""

import threading

import numpy as np
import pytest

from repro.core import AccessLog, TieredChunkStore, TierSpec, ZygoteRegistry
from repro.core.tiers import RamCacheTier, TierReadStats

CHUNK = 4096
FAST_REMOTE = dict(remote_bw=10e9, remote_lat=0.0)


def _payloads(rng, n, size=6000):
    return [rng.integers(0, 255, size, dtype=np.uint8).tobytes()
            for _ in range(n)]


def _fill(store, payloads, pack_id="p0"):
    pack = store.open_pack(pack_id)
    refs = store.put_chunks(pack, payloads)
    pack.close()
    store.save_index()
    return refs


def _tree(seed=0, n=3):
    rng = np.random.default_rng(seed)
    return {
        f"layer{i}": {
            "w": rng.standard_normal((32, 32)).astype(np.float32),
            "b": rng.standard_normal((32,)).astype(np.float32),
        }
        for i in range(n)
    }


def _registry(tmp_path, *, tiers=None):
    reg = ZygoteRegistry(str(tmp_path / "reg"), chunk_bytes=CHUNK, tiers=tiers)
    base_tree = _tree(seed=0)
    reg.register_runtime("fam", base_tree)
    variant = _tree(seed=0)
    variant["layer2"]["w"] = variant["layer2"]["w"] + 0.5
    variant["head"] = {"w": np.full((16, 16), 2.0, np.float32)}
    reg.register_function("fn", "fam", variant)
    log = AccessLog()
    for p in ("layer0/w", "layer0/b", "layer1/w", "layer2/w", "head/w"):
        log.touch(p)
    reg.generate_working_set("fn", log)
    return reg, variant


class TestPlanEpochRace:
    def test_refresh_consistent_under_racing_demote(self, tmp_path):
        """Regression (ISSUE 5 satellite 1): hammer ``restore_plan`` from
        several threads while another thread demotes and prefetches the
        same function's chunks.  Every published (tier_split, epoch) pair
        must be internally consistent — the split always accounts for the
        full unique eager set — and once movement quiesces, the cached
        plan must converge to the store's actual residency instead of
        pinning a stale split under the newest epoch."""
        reg, _ = _registry(
            tmp_path, tiers=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE)
        )
        plan0 = reg.restore_plan("fn", "snapfaas")
        unique = plan0.unique_eager_bytes
        stop = threading.Event()
        errors = []

        def refresher():
            try:
                while not stop.is_set():
                    plan = reg.restore_plan("fn", "snapfaas")
                    split = dict(plan.tier_split)  # atomic dict-ref read
                    assert set(split) <= {"ram", "local", "remote"}, split
                    assert sum(split.values()) == unique, split
            except Exception as e:  # noqa: BLE001 - surfaced after join
                errors.append(e)

        def mover():
            try:
                for _ in range(60):
                    if stop.is_set():
                        break
                    reg.demote_function("fn")
                    reg.prefetch_working_set("fn", "diff")
            except Exception as e:  # noqa: BLE001
                errors.append(e)
            finally:
                stop.set()

        threads = [threading.Thread(target=refresher) for _ in range(4)]
        threads.append(threading.Thread(target=mover))
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors[:3]
        # quiesced: one more refresh must land exactly on reality — the
        # pinned-stale-split bug left this pair permanently inconsistent
        plan = reg.restore_plan("fn", "snapfaas")
        assert plan.residency_epoch == reg.store.residency_epoch
        assert plan.tier_split == reg.store.residency(plan.eager_refs())

    def test_build_stamps_epoch_before_residency(self, tmp_path):
        """A plan built while movement lands mid-``residency()`` must be
        stamped with the *pre-movement* epoch (so the next call refreshes)
        — never a post-movement epoch over pre-movement placement."""
        reg, _ = _registry(
            tmp_path, tiers=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE)
        )
        store = reg.store
        orig_residency = store.residency
        fired = {}

        def racing_residency(refs):
            split = orig_residency(refs)
            if not fired:
                fired["x"] = True
                reg.demote_function("fn")  # movement during the pass
            return split

        store.residency = racing_residency
        try:
            plan = reg.restore_plan("fn", "snapfaas")
        finally:
            store.residency = orig_residency
        # the stale split is detectable: its epoch predates the movement
        assert plan.residency_epoch != store.residency_epoch
        plan2 = reg.restore_plan("fn", "snapfaas")
        assert plan2.tier_split == store.residency(plan2.eager_refs())


class TestTierLookupReadRaces:
    def test_get_chunk_survives_demote_between_lookup_and_read(self, tmp_path):
        """Regression (ISSUE 5 satellite 2): a demote landing between the
        local ``in`` check and the pack read must re-classify through the
        hierarchy and return the right bytes — not KeyError."""
        store = TieredChunkStore(
            str(tmp_path / "s"), spec=TierSpec(ram_bytes=0, **FAST_REMOTE)
        )
        payloads = _payloads(np.random.default_rng(0), 4)
        refs = _fill(store, payloads)
        victim = refs[2]
        orig = store.local.get_chunk
        fired = {}

        def racing(ref):
            if ref.digest == victim.digest and not fired:
                fired["x"] = True
                store.demote([victim])   # moves it remote mid-read
            return orig(ref)

        store.local.get_chunk = racing
        try:
            got = store.get_chunk(victim)
        finally:
            store.local.get_chunk = orig
        assert got == payloads[2]
        # the demote really fired mid-read (the chunk crossed to remote;
        # promote-on-fetch may have since copied it back down)
        assert fired and store.remote.has(victim.digest)

    def test_read_batch_survives_racing_demote(self, tmp_path):
        """Same window for the legacy batched read: the local sub-batch
        re-faults through the hierarchy when a demote races it."""
        store = TieredChunkStore(
            str(tmp_path / "s"), spec=TierSpec(ram_bytes=0, **FAST_REMOTE)
        )
        payloads = _payloads(np.random.default_rng(1), 4)
        refs = _fill(store, payloads)
        orig = store.local.read_batch
        fired = {}

        def racing(batch):
            if not fired:
                fired["x"] = True
                store.demote([refs[1]])
            return orig(batch)

        store.local.read_batch = racing
        try:
            out = store.read_batch(refs)
        finally:
            store.local.read_batch = orig
        for ref, payload in zip(refs, payloads):
            assert out[ref.digest] == payload

    def test_scatter_reads_byte_identical_under_movement_storm(self, tmp_path):
        """Sustained concurrent movement (demote/prefetch cycles) against
        looping scatter-reads: every read returns byte-identical content —
        a reader can never see a digest the residency snapshot claimed
        resident but the tier already evicted."""
        store = TieredChunkStore(
            str(tmp_path / "s"),
            spec=TierSpec(ram_bytes=24_000, **FAST_REMOTE),
        )
        rng = np.random.default_rng(2)
        payloads = _payloads(rng, 10)
        refs = _fill(store, payloads)
        expected = {r.digest: p for r, p in zip(refs, payloads)}
        stop = threading.Event()
        errors = []

        def reader(seed):
            r = np.random.default_rng(seed)
            try:
                while not stop.is_set():
                    order = list(r.permutation(len(refs)))
                    batch = [refs[i] for i in order]
                    bufs = [bytearray(ref.size) for ref in batch]
                    stats = TierReadStats()
                    store.read_batch_into(
                        [(ref, memoryview(b)) for ref, b in zip(batch, bufs)],
                        stats=stats,
                    )
                    for ref, buf in zip(batch, bufs):
                        assert bytes(buf) == expected[ref.digest], ref.digest
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        def mover():
            r = np.random.default_rng(99)
            try:
                for _ in range(40):
                    if stop.is_set():
                        break
                    pick = [refs[i] for i in r.permutation(len(refs))[:4]]
                    store.demote(pick)
                    store.prefetch(pick)
            except Exception as e:  # noqa: BLE001
                errors.append(e)
            finally:
                stop.set()

        threads = [threading.Thread(target=reader, args=(s,)) for s in range(3)]
        threads.append(threading.Thread(target=mover))
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=180)
        store.join_promotions()
        assert not errors, errors[:3]


class TestRamResidencyAdvertised:
    def test_lru_eviction_bumps_epoch(self, tmp_path):
        """An LRU eviction is tier movement: it must bump the residency
        epoch so cached splits claiming the digest RAM-resident go stale
        (it used to be the one movement nothing advertised)."""
        store = TieredChunkStore(
            str(tmp_path / "s"), spec=TierSpec(ram_bytes=8_000)
        )
        payloads = _payloads(np.random.default_rng(3), 2)
        refs = _fill(store, payloads)
        store.prefetch([refs[0]])
        assert store.tier_of(refs[0].digest) == "ram"
        e0 = store.residency_epoch
        store.prefetch([refs[1]])      # capacity holds one: evicts refs[0]
        assert store.tier_of(refs[0].digest) == "local"
        assert store.residency_epoch > e0

    def test_ram_callback_fires_on_removals_outside_lock(self):
        """Removals (evicting put, discard, clear) fire the callback after
        the RAM lock drops (it may re-enter tier state — the store's epoch
        bump takes its own lock); plain insertions do NOT fire (per-insert
        bumps would invalidate every cached plan on every demand fault)."""
        tier = RamCacheTier(10)
        seen = []

        def cb():
            # would deadlock if invoked under tier._lock
            assert not tier._lock.locked()
            seen.append(tier.used)

        tier._on_change = cb
        tier.put("a", b"12345")    # plain insertion: silent
        assert seen == []
        tier.put("b", b"123456")   # evicts "a": fires
        assert len(seen) == 1
        tier.put("c", b"1234")     # fits alongside "b": silent
        assert len(seen) == 1
        tier.discard(["b"])        # fires
        tier.clear()               # "c" still resident: fires
        assert len(seen) == 3


# --------------------------------------------------------------------------
# regressions for the races the static analyzer (repro.analysis) surfaced


class TestCategoryRefsPublishRace:
    def test_stale_ws_refs_never_republished(self, tmp_path, monkeypatch):
        """Regression (guards pass, G001 on ``category_refs``): the old
        ``_category_refs`` computed lock-free and published under no lock,
        so a compute racing ``generate_working_set``'s swap-and-clear could
        re-publish refs cut from the dead working set — permanently, since
        nothing would ever invalidate them again.  Compute and publish now
        both run under ``plan_lock``; this pins the interleaving with a
        blocked ``resolve`` and asserts the WS swap (a) waits for the
        in-flight compute and (b) leaves the cache invalidated, not stale."""
        from repro.core import registry as registry_mod

        reg, _ = _registry(tmp_path)
        rec = reg.functions["fn"]

        entered = threading.Event()
        release = threading.Event()
        real_resolve = registry_mod.resolve
        # only the first resolve() after arming blocks — that is the
        # compute thread's call, because the swapper starts later
        armed = [True]

        def slow_resolve(*args, **kwargs):
            if armed and armed.pop():
                entered.set()
                assert release.wait(timeout=10)
            return real_resolve(*args, **kwargs)

        monkeypatch.setattr(registry_mod, "resolve", slow_resolve)

        stale_out = {}

        def compute():
            stale_out["refs"] = reg._category_refs("fn")

        computer = threading.Thread(target=compute)
        computer.start()
        assert entered.wait(timeout=10)

        # swap the working set down to a strict subset while the compute
        # is parked inside its critical section
        small_log = AccessLog()
        small_log.touch("layer0/w")
        swapper = threading.Thread(
            target=reg.generate_working_set, args=("fn", small_log)
        )
        swapper.start()
        # the swap's plan_lock section must wait for the in-flight compute
        swapper.join(timeout=0.4)
        assert swapper.is_alive(), (
            "generate_working_set finished while _category_refs was still "
            "inside its critical section: publish is not serialised"
        )

        release.set()
        computer.join(timeout=10)
        swapper.join(timeout=10)
        assert not computer.is_alive() and not swapper.is_alive()

        # the swap ran last: the stale publish must be gone
        with rec.plan_lock:
            assert rec.category_refs is None, (
                "stale category_refs survived the working-set swap"
            )
        fresh = reg._category_refs("fn")
        assert len(fresh["ws"]) < len(stale_out["refs"]["ws"]), (
            "fresh refs should reflect the shrunken working set"
        )


class TestTierCounterExactness:
    def test_concurrent_prefetch_counts_every_byte(self, tmp_path):
        """Regression (guards pass, G001 on the telemetry counters): the
        tier counters were bumped with plain ``+=`` — a racy
        read-modify-write that loses updates under concurrent prefetches.
        All counter mutations now take ``_stats_lock``; disjoint parallel
        prefetches must account for every byte exactly."""
        rng = np.random.default_rng(7)
        n_threads, per_thread = 8, 4
        payloads = _payloads(rng, n_threads * per_thread)
        store = TieredChunkStore(
            str(tmp_path / "s"),
            spec=TierSpec(ram_bytes=64 << 20, **FAST_REMOTE),
        )
        refs = _fill(store, payloads)
        assert store.demote(refs) == sum(len(p) for p in payloads)

        slices = [
            refs[i * per_thread:(i + 1) * per_thread]
            for i in range(n_threads)
        ]
        barrier = threading.Barrier(n_threads)
        errors = []

        def prefetcher(chunk_refs):
            try:
                barrier.wait(timeout=10)
                store.prefetch(chunk_refs)
            except Exception as e:  # pragma: no cover - surfaced below
                errors.append(e)

        threads = [threading.Thread(target=prefetcher, args=(s,))
                   for s in slices]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        assert not errors, errors
        store.join_promotions()

        total = sum(r.size for r in refs)
        stats = store.tier_stats()
        assert stats["prefetched_bytes"] == total, (
            f"lost counter updates: {stats['prefetched_bytes']} != {total}"
        )
        assert all(store.tier_of(r.digest) == "ram" for r in refs)
