"""Planned restore engine: equivalence with the seed path, scatter-read
correctness, coalescing properties, and the on-device patch path."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AccessLog,
    ChunkStore,
    ZygoteRegistry,
    flatten_pytree,
)
from repro.core.chunkstore import COALESCE_GAP, coalesce_ranges, scan_chunks

CHUNK = 4096


def _tree(seed=0, n=3, rows=128, cols=32):
    rng = np.random.default_rng(seed)
    return {
        f"layer{i}": {
            "w": rng.standard_normal((rows, cols)).astype(np.float32),
            "b": rng.standard_normal((cols,)).astype(np.float32),
        }
        for i in range(n)
    }


def _registry(tmp_path, *, ws=True):
    reg = ZygoteRegistry(str(tmp_path / "reg"), chunk_bytes=CHUNK)
    base_tree = _tree(seed=0)
    reg.register_runtime("fam", base_tree)
    variant = _tree(seed=0)
    variant["layer2"]["w"] = variant["layer2"]["w"] + 0.5       # dirty array
    variant["layer1"]["w"][:8] = 0.0                            # zeroed rows
    variant["head"] = {"w": np.full((16, 16), 2.0, np.float32)}  # new array
    reg.register_function("fn", "fam", variant)
    if ws:
        log = AccessLog()
        for p in ("layer0/w", "layer0/b", "layer1/w", "layer2/w", "head/w"):
            log.touch(p)
        reg.generate_working_set("fn", log)
    return reg, variant


# ---------------------------------------------------------- engine equivalence

class TestEngineEquivalence:
    @pytest.mark.parametrize("strategy", ["snapfaas", "snapfaas-", "reap"])
    def test_planned_matches_legacy_bytes(self, tmp_path, strategy):
        """Restored bytes from the plan-based path are byte-identical to the
        seed (legacy) path, for every array and every snapshot strategy."""
        reg, variant = _registry(tmp_path)
        legacy = reg.cold_start("fn", strategy, engine="legacy")
        planned = reg.cold_start("fn", strategy, engine="planned")
        assert set(legacy.arrays) == set(planned.arrays)
        for path in legacy.arrays:
            a, b = legacy.value(path), planned.value(path)
            assert a.dtype == b.dtype and a.shape == b.shape, path
            np.testing.assert_array_equal(a, b, err_msg=f"{strategy}/{path}")

    @pytest.mark.parametrize("strategy", ["snapfaas", "snapfaas-", "reap"])
    def test_planned_matches_source_variant(self, tmp_path, strategy):
        reg, variant = _registry(tmp_path)
        inst = reg.cold_start("fn", strategy, engine="planned")
        for path, expected in flatten_pytree(variant).items():
            np.testing.assert_array_equal(inst.value(path), expected, err_msg=path)

    def test_seuss_and_regular_match(self, tmp_path):
        """The loader strategies restore the same values (they bypass the
        plan engine; included so all five strategies are pinned here)."""
        reg, variant = _registry(tmp_path)
        flat = flatten_pytree(variant)
        src = lambda: {p: np.array(a) for p, a in flat.items()
                       if "head" in p or "layer2/w" in p or "layer1/w" in p}
        base = lambda: {p: np.array(a) for p, a in flat.items()}
        for strategy, kw in (
            ("seuss", dict(source_loader=src)),
            ("regular", dict(source_loader=src, base_loader=base)),
        ):
            inst = reg.cold_start("fn", strategy, **kw)
            for path, expected in flat.items():
                np.testing.assert_array_equal(
                    inst.value(path), expected, err_msg=f"{strategy}/{path}"
                )

    def test_plan_is_cached_and_invalidated(self, tmp_path):
        reg, _ = _registry(tmp_path)
        reg.cold_start("fn", "snapfaas")
        rec = reg.functions["fn"]
        plan = rec.plans["snapfaas"]
        reg.cold_start("fn", "snapfaas")
        assert rec.plans["snapfaas"] is plan  # cached, not rebuilt
        reg.generate_working_set("fn", AccessLog())  # WS change → stale
        assert not rec.plans

    def test_eager_accounting_matches_legacy(self, tmp_path):
        reg, _ = _registry(tmp_path)
        for strategy in ("snapfaas", "snapfaas-", "reap"):
            a = reg.cold_start("fn", strategy, engine="legacy").metrics
            b = reg.cold_start("fn", strategy, engine="planned").metrics
            assert a.eager_bytes == b.eager_bytes, strategy
            assert a.eager_chunks == b.eager_chunks, strategy

    def test_demand_paging_still_works(self, tmp_path):
        """With an empty WS nothing is eager; first read demand-faults."""
        reg, variant = _registry(tmp_path)
        reg.generate_working_set("fn", AccessLog())
        inst = reg.cold_start("fn", "snapfaas", engine="planned")
        assert inst.metrics.eager_bytes == 0
        np.testing.assert_array_equal(
            inst.value("layer2/w"), variant["layer2"]["w"]
        )
        assert inst.metrics.demand_chunks > 0


# ------------------------------------------------------------- scatter reads

class TestReadBatchInto:
    def _store(self, tmp_path, n=40, size=5000, seed=0):
        store = ChunkStore(str(tmp_path / "s"))
        rng = np.random.default_rng(seed)
        payloads = [rng.integers(0, 255, size, dtype=np.uint8).tobytes()
                    for _ in range(n)]
        payloads[5] = b"\x00" * size
        pack = store.open_pack("p0")
        refs = store.put_chunks(pack, payloads)
        pack.close()
        return store, refs, payloads

    def test_scatter_into_views(self, tmp_path):
        store, refs, payloads = self._store(tmp_path)
        big = np.zeros(sum(r.size for r in refs), dtype=np.uint8)
        mv = memoryview(big)
        dests, off = [], 0
        for r in refs:
            dests.append((r, mv[off : off + r.size]))
            off += r.size
        store.read_batch_into(dests)
        assert bytes(big.tobytes()) == b"".join(
            b"\x00" * r.size if r.zero else p for r, p in zip(refs, payloads)
        )

    def test_duplicate_refs_read_once_replicated(self, tmp_path):
        store, refs, payloads = self._store(tmp_path)
        r = refs[0]
        bufs = [bytearray(r.size) for _ in range(4)]
        store.read_batch_into([(r, memoryview(b)) for b in bufs])
        assert all(bytes(b) == payloads[0] for b in bufs)

    def test_wrong_dest_size_raises(self, tmp_path):
        store, refs, _ = self._store(tmp_path)
        with pytest.raises(ValueError):
            store.read_batch_into([(refs[0], memoryview(bytearray(3)))])

    def test_serial_equals_parallel(self, tmp_path):
        store, refs, payloads = self._store(tmp_path, n=64)
        out = {}
        for parallel in (False, True):
            bufs = [bytearray(r.size) for r in refs]
            store.read_batch_into(
                [(r, memoryview(b)) for r, b in zip(refs, bufs)],
                parallel=parallel,
            )
            out[parallel] = [bytes(b) for b in bufs]
        assert out[False] == out[True]

    def test_read_batch_dedupes_repeats(self, tmp_path):
        """The same digest requested N times is planned once (seed appended
        it to by_pack N times) and still returned correctly."""
        store, refs, payloads = self._store(tmp_path, n=8)
        batch = store.read_batch(list(refs) * 5)
        for r, p in zip(refs, payloads):
            if r.zero:
                assert r.digest not in batch
            else:
                assert batch[r.digest] == p

    def test_scan_chunks_matches_per_chunk(self, tmp_path):
        rng = np.random.default_rng(3)
        blob = rng.integers(0, 255, 50000, dtype=np.uint8)
        blob[10000:20000] = 0
        buf = memoryview(blob.tobytes())
        from repro.core.chunkstore import chunk_digest, chunk_payloads, is_zero
        refs = scan_chunks(buf, 10000)
        for ref, p in zip(refs, chunk_payloads(buf, 10000)):
            assert ref.zero == is_zero(p)
            if not ref.zero:
                assert ref.digest == chunk_digest(p)
            assert ref.size == len(p)


# --------------------------------------------------------------- properties

ranges_strategy = st.lists(
    st.tuples(st.integers(0, 1 << 20), st.integers(1, 1 << 16)),
    min_size=0, max_size=64,
)


class TestCoalesceProperties:
    @settings(max_examples=50, deadline=None)
    @given(ranges=ranges_strategy, gap=st.sampled_from([0, 1, 4096, COALESCE_GAP]))
    def test_runs_cover_partition_and_order(self, ranges, gap):
        """INVARIANTS of the scatter-read planner:
        * every input range is a member of exactly one run;
        * each run covers all its members;
        * runs are sorted, non-overlapping, and separated by > gap;
        * within a run, consecutive members (in offset order) are ≤ gap apart.
        """
        runs = coalesce_ranges(ranges, gap=gap)
        seen = []
        prev_end = None
        for start, end, members in runs:
            assert members, "empty run"
            assert start < end
            if prev_end is not None:
                assert start > prev_end + gap  # else they would have merged
            prev_end = end
            last_end = None
            for i in members:
                off, size = ranges[i]
                assert start <= off and off + size <= end
                if last_end is not None:
                    assert off <= last_end + gap
                last_end = max(last_end or 0, off + size)
            assert min(ranges[i][0] for i in members) == start
            assert max(ranges[i][0] + ranges[i][1] for i in members) == end
            seen.extend(members)
        assert sorted(seen) == list(range(len(ranges)))

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), nzero=st.integers(0, 6))
    def test_roundtrip_random_store(self, tmp_path_factory, seed, nzero):
        """INVARIANT: scatter-read returns exactly what was stored, for any
        mix of zero/non-zero/duplicate chunks."""
        tmp = tmp_path_factory.mktemp("rb")
        store = ChunkStore(str(tmp / "s"))
        rng = np.random.default_rng(seed)
        payloads = []
        for i in range(12):
            if i < nzero:
                payloads.append(b"\x00" * int(rng.integers(1, 9000)))
            else:
                payloads.append(
                    rng.integers(0, 255, int(rng.integers(1, 9000)),
                                 dtype=np.uint8).tobytes()
                )
        pack = store.open_pack("p")
        refs = store.put_chunks(pack, payloads)
        pack.close()
        order = rng.permutation(len(refs))
        bufs = {int(i): bytearray(refs[i].size) for i in order}
        store.read_batch_into([(refs[i], memoryview(bufs[i])) for i in bufs])
        for i, b in bufs.items():
            expect = b"\x00" * refs[i].size if refs[i].zero else payloads[i]
            assert bytes(b) == expect


# ------------------------------------------------------------- device patch

class TestDevicePatch:
    def test_patch_descriptor_matches_host_assembly(self, tmp_path):
        """Applying (sel, rows) over the pool content must reproduce the
        host-assembled array — validates the layout fed to the Pallas
        snapshot_patch kernel."""
        reg, variant = _registry(tmp_path)
        inst = reg.cold_start("fn", "snapfaas", engine="planned")
        ma = inst.arrays["layer2/w"]
        assert ma.patch is not None
        meta = ma.meta
        pool_arr = reg.pools["fam"].get("layer2/w")
        flat = np.array(pool_arr).reshape(-1).view(np.uint8).copy()
        rows = ma.patch.rows_2d()
        cb = meta.chunk_bytes
        for idx, sel_row in enumerate(ma.patch.sel):
            if sel_row < 0:
                continue
            lo = idx * cb
            size = min(cb, meta.nbytes - lo)
            flat[lo : lo + size] = rows[sel_row, :size]
        patched = flat.view(np.dtype(meta.dtype)).reshape(meta.shape)
        np.testing.assert_array_equal(patched, variant["layer2"]["w"])
        # and the host lazy path agrees
        np.testing.assert_array_equal(inst.value("layer2/w"), patched)

    def test_patch_apply_op_on_descriptor(self, tmp_path):
        """End-to-end: the jitted patch op over the plan's descriptor equals
        the variant array (this is exactly what the worker runs on-device)."""
        import jax.numpy as jnp
        from repro.kernels.snapshot_patch import patch_apply_op

        reg, variant = _registry(tmp_path)
        inst = reg.cold_start("fn", "snapfaas", engine="planned")
        ma = inst.arrays["layer2/w"]
        meta = ma.meta
        itemsize = np.dtype(meta.dtype).itemsize
        c = meta.chunk_bytes // itemsize
        n = meta.num_chunks()
        total = meta.nbytes // itemsize
        base = np.array(reg.pools["fam"].get("layer2/w")).reshape(-1)
        base = np.pad(base, (0, n * c - total))
        diff2d = ma.patch.rows_2d().view(np.dtype(meta.dtype))
        out = patch_apply_op(
            jnp.asarray(base.reshape(n, c)), jnp.asarray(diff2d),
            jnp.asarray(ma.patch.sel), mode="replace",
            interpret=True, use_kernel=False,
        )
        got = np.asarray(out).reshape(-1)[:total].reshape(meta.shape)
        np.testing.assert_array_equal(got, variant["layer2"]["w"])

    def test_worker_serves_patched_params(self, tmp_path):
        """Worker request path picks the device-patch branch and produces
        the same logits as a host-assembled instance."""
        jax = pytest.importorskip("jax")
        from repro.models import build_model
        from repro.models.config import ModelConfig
        from repro.serving.trace import request_tokens
        from repro.serving.worker import FunctionSpec, Worker

        cfg = ModelConfig(
            name="t", family="dense", num_layers=2, d_model=64, num_heads=2,
            num_kv_heads=2, d_ff=128, vocab_size=256, tie_embeddings=True,
            dtype="float32",
        )
        model = build_model(cfg)
        worker = Worker(str(tmp_path / "w"), chunk_bytes=4096)
        base_params = model.init(0)
        worker.register_runtime("t", model, base_params)
        flat = flatten_pytree(jax.tree.map(np.asarray, base_params))
        variant = {k: np.array(v) for k, v in flat.items()}
        for k in variant:
            if k.endswith("wq"):
                variant[k] = variant[k] + 0.01
        spec = FunctionSpec(name="fn", family="t", variant=variant)
        worker.register_function(spec)
        from repro.serving import ColdStartOptions, InvocationRequest, Strategy

        toks = request_tokens(spec, np.random.default_rng(0), cfg.vocab_size,
                              seq=8)

        def cold(engine=None):
            return worker.invoke(InvocationRequest(
                function="fn", tokens=toks,
                options=ColdStartOptions(strategy=Strategy.SNAPFAAS,
                                         force_cold=True, engine=engine),
            ))

        r_planned = cold()
        inst = worker.pool.get("fn")
        assert any(a._dev is not None for a in inst.arrays.values()), \
            "device patch path did not fire"
        r_legacy = cold(engine="legacy")
        np.testing.assert_allclose(r_planned.output, r_legacy.output,
                                   rtol=1e-5, atol=1e-6)
