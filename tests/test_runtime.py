"""Runtime integration tests: data pipeline, trainer fault tolerance,
serving cold-start correctness, gradient compression."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import ShardedLoader, _batch_from_counter
from repro.models import build_model
from repro.optim import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


# ---------------------------------------------------------------- pipeline

class TestPipeline:
    def test_deterministic(self):
        a = _batch_from_counter(0, shard=1, step=5, batch=2, seq=8, vocab=100)
        b = _batch_from_counter(0, shard=1, step=5, batch=2, seq=8, vocab=100)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = _batch_from_counter(0, shard=2, step=5, batch=2, seq=8, vocab=100)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_steal_resumes_exactly(self):
        """A stolen shard continues the victim's stream with no gap."""
        victim = ShardedLoader(seed=0, vocab=100, seq_len=8, batch_per_shard=2,
                               num_shards=2, owned=[1])
        v1 = victim.next()
        v2_expected = _batch_from_counter(0, 1, 1, 2, 8, 100)
        at = victim.release(1)
        thief = ShardedLoader(seed=0, vocab=100, seq_len=8, batch_per_shard=2,
                              num_shards=2, owned=[0])
        thief.steal(1, at)
        t = thief.next()
        # thief's batch = shard0 step0 ++ shard1 step1
        np.testing.assert_array_equal(t["tokens"][2:], v2_expected["tokens"])

    def test_state_dict_roundtrip(self):
        l = ShardedLoader(seed=0, vocab=100, seq_len=8, batch_per_shard=2,
                          num_shards=1, owned=[0])
        l.next(); l.next()
        sd = l.state_dict()
        l2 = ShardedLoader(seed=0, vocab=100, seq_len=8, batch_per_shard=2,
                           num_shards=1, owned=[0])
        l2.load_state_dict(sd)
        np.testing.assert_array_equal(l.next()["tokens"], l2.next()["tokens"])

    def test_prefetch_thread(self):
        l = ShardedLoader(seed=0, vocab=100, seq_len=8, batch_per_shard=2,
                          num_shards=1, owned=[0])
        l.start()
        b1 = l.next()
        b2 = l.next()
        l.stop()
        assert b1["tokens"].shape == (2, 8)
        assert not np.array_equal(b1["tokens"], b2["tokens"])


# ------------------------------------------------------------------ trainer

def _tiny_trainer(tmp_path, **kw):
    cfg = reduced(get_config("stablelm-3b"))
    model = build_model(cfg)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    loader = ShardedLoader(seed=0, vocab=cfg.vocab_size, seq_len=32,
                           batch_per_shard=2, num_shards=1, owned=[0])
    tcfg = TrainerConfig(workdir=str(tmp_path / "run"), checkpoint_every=3,
                         async_checkpoint=False, **kw)
    return Trainer(model, opt, loader, tcfg), loader


class TestTrainer:
    def test_loss_decreases(self, tmp_path):
        tr, _ = _tiny_trainer(tmp_path)
        tr.init_state()
        tr.train(8)
        losses = [m["loss"] for m in tr.metrics_log]
        assert losses[-1] < losses[0]
        assert all(np.isfinite(l) for l in losses)

    def test_crash_resume_continues_stream(self, tmp_path):
        """Crash at step 6, resume → training state and DATA CURSOR restored;
        the resumed run must produce the same loss trajectory as an
        uninterrupted run."""
        tr1, _ = _tiny_trainer(tmp_path)
        tr1.init_state()
        with pytest.raises(RuntimeError):
            tr1.train(10, fail_at=6)
        # fresh process analogue: new trainer over the same workdir
        tr2, _ = _tiny_trainer(tmp_path)
        assert tr2.resume()
        assert tr2.step == 6  # checkpoint_every=3 → last ckpt at step 6
        tr2.train(4)
        # uninterrupted reference
        ref, _ = _tiny_trainer(tmp_path / "ref" if False else tmp_path.joinpath("ref"))
        ref.init_state()
        ref.train(10)
        got = [m["loss"] for m in tr1.metrics_log] + [m["loss"] for m in tr2.metrics_log]
        want = [m["loss"] for m in ref.metrics_log]
        np.testing.assert_allclose(got[:6] + got[6:], want, rtol=1e-4)

    def test_checkpoint_dedup(self, tmp_path):
        """Adjacent checkpoints share most chunks (content addressing)."""
        tr, _ = _tiny_trainer(tmp_path)
        tr.init_state()
        tr.train(3)  # ckpt at step 3
        b1 = tr.store.stored_bytes()
        tr.train(3)  # ckpt at step 6
        b2 = tr.store.stored_bytes()
        # second checkpoint adds < 2.2x of the first (dedup of unchanged
        # state: step counters/opt state change, embeddings partially)
        assert b2 < 2.2 * b1

    def test_straggler_steal(self, tmp_path):
        cfg = reduced(get_config("stablelm-3b"))
        model = build_model(cfg)
        opt = OptimizerConfig(lr=1e-3)
        fast = ShardedLoader(seed=0, vocab=cfg.vocab_size, seq_len=16,
                             batch_per_shard=2, num_shards=2, owned=[0])
        slow = ShardedLoader(seed=0, vocab=cfg.vocab_size, seq_len=16,
                             batch_per_shard=2, num_shards=2, owned=[1],
                             delay_s=0.3)
        for _ in range(5):
            fast._produce(); slow._produce()
        tcfg = TrainerConfig(workdir=str(tmp_path / "w"), watchdog_factor=2.0,
                             async_checkpoint=False)
        tr = Trainer(model, opt, fast, tcfg, peer_loaders=[slow])
        tr._watchdog()
        assert tr.steals and tr.steals[0]["shard"] == 1
        assert 1 in fast.owned and 1 not in slow.owned


# ------------------------------------------------------------------ serving

def _invoke(worker, fn, tokens, *, strategy="snapfaas", force_cold=False):
    from repro.serving import ColdStartOptions, InvocationRequest, Strategy

    return worker.invoke(InvocationRequest(
        function=fn, tokens=np.asarray(tokens),
        options=ColdStartOptions(strategy=Strategy.coerce(strategy),
                                 force_cold=force_cold),
    ))


class TestServing:
    @pytest.fixture(scope="class")
    def worker_and_specs(self, tmp_path_factory):
        from repro.serving.trace import build_functions
        root = str(tmp_path_factory.mktemp("serve"))
        cfg = reduced(get_config("gemma-2b"))
        model = build_model(cfg)
        return build_functions(root, cfg, model, n_functions=3), cfg

    def test_all_strategies_same_output(self, worker_and_specs):
        """Cold starts under every strategy produce identical logits —
        restoration is value-preserving no matter the path."""
        (worker, specs), cfg = worker_and_specs
        rng = np.random.default_rng(0)
        from repro.serving.trace import request_tokens
        outs = {}
        for strat in ("regular", "reap", "seuss", "snapfaas-", "snapfaas"):
            toks = request_tokens(specs[0], np.random.default_rng(7), cfg.vocab_size)
            r = _invoke(worker, specs[0].name, toks, strategy=strat, force_cold=True)
            outs[strat] = r.output
        ref = outs["regular"]
        for strat, o in outs.items():
            np.testing.assert_allclose(o, ref, rtol=1e-5, atol=1e-5,
                                       err_msg=strat)

    def test_warm_hit_skips_boot(self, worker_and_specs):
        (worker, specs), cfg = worker_and_specs
        from repro.serving.trace import request_tokens
        toks = request_tokens(specs[1], np.random.default_rng(3), cfg.vocab_size)
        r1 = _invoke(worker, specs[1].name, toks, force_cold=True)
        r2 = _invoke(worker, specs[1].name, toks)
        assert r1.cold and not r2.cold
        assert r2.boot_s == 0.0
        np.testing.assert_allclose(r1.output, r2.output, rtol=1e-6)

    def test_snapfaas_eager_less_than_minus(self, worker_and_specs):
        """WS restore reads fewer bytes eagerly than full-diff restore."""
        (worker, specs), cfg = worker_and_specs
        from repro.serving.trace import request_tokens
        spec = specs[0]  # adapter: row-granular WS
        toks = request_tokens(spec, np.random.default_rng(5), cfg.vocab_size)
        r_ws = _invoke(worker, spec.name, toks, force_cold=True)
        r_full = _invoke(worker, spec.name, toks, strategy="snapfaas-", force_cold=True)
        assert r_ws.metrics.eager_bytes <= r_full.metrics.eager_bytes

    def test_stray_access_is_correct(self, worker_and_specs):
        """Tokens OUTSIDE the WS rows still produce correct results (the
        stray chunks demand-fault in, like REAP page faults)."""
        (worker, specs), cfg = worker_and_specs
        spec = specs[0]
        stray = np.asarray([[cfg.vocab_size - 1, 0, 1, 2]], np.int32)
        r_cold = _invoke(worker, spec.name, stray, force_cold=True)
        r_reg = _invoke(worker, spec.name, stray, strategy="regular", force_cold=True)
        np.testing.assert_allclose(r_cold.output, r_reg.output, rtol=1e-5, atol=1e-5)

    def test_pool_eviction(self):
        from repro.serving.worker import InstancePool
        pool = InstancePool(budget_bytes=100)
        pool.put("a", object(), 60)  # type: ignore[arg-type]
        pool.put("b", object(), 60)  # type: ignore[arg-type]
        assert pool.get("a") is None  # evicted
        assert pool.get("b") is not None


# ------------------------------------------------------------ compression

class TestCompression:
    def test_quantize_roundtrip(self):
        from repro.distrib.compress import dequantize_int8, quantize_int8
        x = jnp.asarray(np.random.default_rng(0).standard_normal(256), jnp.float32)
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)).max()
        assert err <= float(s) * 0.51 + 1e-9

    def test_ef_compressed_mean_subprocess(self):
        """Runs on 4 fake devices in a subprocess (XLA flag must precede
        jax init)."""
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.distrib.compress import ef_compressed_mean
mesh = jax.make_mesh((4,), ("pod",))
rng = np.random.default_rng(0)
parts = jnp.asarray(rng.standard_normal((4, 64, 32)), jnp.float32)
err = jnp.zeros_like(parts)
true_mean = np.asarray(parts).mean(0)
# one shot: quantization error bounded
mean, err = ef_compressed_mean(parts, err, mesh, "pod")
got = np.asarray(mean)[0]
assert np.abs(got - true_mean).max() < 0.05, np.abs(got - true_mean).max()
# error feedback: the residual is carried, not lost
assert float(jnp.abs(err).sum()) > 0
# repeated same-gradient steps: EF-corrected stream averages to the truth
acc = np.zeros_like(true_mean); e = jnp.zeros_like(parts)
for i in range(20):
    m, e = ef_compressed_mean(parts, e, mesh, "pod")
    acc += np.asarray(m)[0]
acc /= 20
assert np.abs(acc - true_mean).max() < 0.01, np.abs(acc - true_mean).max()
print("OK")
"""
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
        assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]
