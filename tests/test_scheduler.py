"""Scheduler tests: placement policies (determinism, affinity
co-location, load avoidance), the work-stealing gates against the
single-flight protocol, queue-driven autoscaling hysteresis, the
executor width derived from admission caps, and the serving-sample
reservoir."""

import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.serving import (
    AdmissionConfig,
    AffinityPlacement,
    AutoscaleConfig,
    Autoscaler,
    InvocationRequest,
    PLACEMENTS,
    StaticHashPlacement,
    StealConfig,
    WorkerView,
    make_placement,
)
from repro.serving.cluster import Cluster, _Reservoir, _shard_of


def _view(wid, *, depth=0, n_fns=0, cost=0.0, warm=False, registered=False,
          siblings=0):
    return WorkerView(worker_id=wid, queue_depth=depth, n_functions=n_fns,
                      assigned_cost_s=cost, warm=warm, registered=registered,
                      siblings=siblings)


# ------------------------------------------------------- placement (pure)

class TestPlacementPolicies:
    def test_registry_and_coercion(self):
        assert set(PLACEMENTS) == {"static", "affinity"}
        assert isinstance(make_placement("static"), StaticHashPlacement)
        assert isinstance(make_placement("affinity"), AffinityPlacement)
        assert isinstance(make_placement(None), StaticHashPlacement)
        custom = AffinityPlacement(load_weight=2.0)
        assert make_placement(custom) is custom
        with pytest.raises(ValueError):
            make_placement("round-robin")

    def test_static_matches_stable_shard(self):
        views = [_view(i) for i in range(4)]
        pol = StaticHashPlacement()
        for fn in ("lorem", "matmul", "ocr"):
            assert pol.place(fn, views) == _shard_of(fn, 4)

    def test_affinity_is_deterministic(self):
        views = [_view(0, depth=2), _view(1, warm=True), _view(2, n_fns=1)]
        pol = AffinityPlacement()
        first = pol.place("fn", views)
        assert all(pol.place("fn", views) == first for _ in range(10))

    def test_affinity_prefers_sibling_colocation(self):
        # the sibling pull (chunk-sharing affinity) outweighs a small
        # load difference: dedup siblings should share a warm base
        views = [_view(0, n_fns=0), _view(1, n_fns=2, siblings=2)]
        assert AffinityPlacement().place("fn", views) == 1

    def test_affinity_sibling_pull_is_capped(self):
        # a huge family cannot absorb every worker: past sibling_cap the
        # load terms win again
        pol = AffinityPlacement(sibling_cap=2)
        crowded = _view(1, n_fns=12, siblings=12)
        empty = _view(0)
        assert pol.place("fn", [empty, crowded]) == 0

    def test_affinity_avoids_deep_queues(self):
        views = [_view(0, depth=5), _view(1, depth=0)]
        assert AffinityPlacement().place("fn", views) == 1

    def test_affinity_prefers_warm_and_breaks_ties_low(self):
        warm = [_view(0), _view(1, warm=True)]
        assert AffinityPlacement().place("fn", warm) == 1
        tied = [_view(0), _view(1), _view(2)]
        assert AffinityPlacement().place("fn", tied) == 0

    def test_affinity_counts_assigned_cost(self):
        # one expensive fine-tune weighs more than two cheap adapters
        views = [_view(0, n_fns=1, cost=3.0), _view(1, n_fns=2, cost=0.1)]
        assert AffinityPlacement().place("fn", views) == 1


class TestStealConfigValidation:
    def test_defaults_are_consistent(self):
        cfg = StealConfig()
        assert cfg.min_cold_depth >= cfg.min_depth

    def test_rejects_bad_bounds(self):
        with pytest.raises(ValueError):
            StealConfig(min_depth=0)
        with pytest.raises(ValueError):
            StealConfig(max_cold_s=-1.0)
        with pytest.raises(ValueError):
            StealConfig(min_depth=3, min_cold_depth=2)


# -------------------------------------------------------------- reservoir

class TestReservoir:
    def test_uniform_over_stream_not_newest_tail(self):
        # regression: the old deque(maxlen=cap) kept only the newest cap
        # samples, so percentiles described the drained tail of a replay
        r = _Reservoir(64)
        for i in range(10_000):
            r.add(i)
        assert r.n_seen == 10_000 and len(r) == 64
        sample = r.snapshot()
        assert min(sample) < 2_000          # deque would start at 9_936
        assert 3_000 < np.mean(sample) < 7_000

    def test_keeps_everything_under_cap(self):
        r = _Reservoir(16)
        for i in range(10):
            r.add(i)
        assert sorted(r.snapshot()) == list(range(10))

    def test_seeded_and_deterministic(self):
        a, b = _Reservoir(8, seed=1), _Reservoir(8, seed=1)
        for i in range(1000):
            a.add(i)
            b.add(i)
        assert a.snapshot() == b.snapshot()


# ------------------------------------------------- autoscaler (unit, fakes)

class _FakeController:
    def __init__(self):
        self.depth = 0
        self.lanes = []
        self.closed = []

    def max_open_depth(self):
        return self.depth

    def add_lane(self, worker):
        self.lanes.append(worker.worker_id)

    def shallowest_open_lane(self):
        return self.closed[-1] + 1 if self.closed else 1

    def close_lane(self, wid):
        self.closed.append(wid)
        return True


class _FakeCluster:
    def __init__(self):
        self.n = 1
        self._clock = time.perf_counter
        self.ups = []
        self.downs = []

    def n_active(self):
        return self.n

    def scale_up(self, *, t_s, lane_depth):
        self.n += 1
        self.ups.append(lane_depth)
        return SimpleNamespace(worker_id=self.n - 1)

    def retire_worker(self, wid, *, t_s, lane_depth):
        self.n -= 1
        self.downs.append(wid)


class TestAutoscalerHysteresis:
    def test_scales_up_on_sustained_depth_and_down_when_quiet(self):
        cluster, ctrl = _FakeCluster(), _FakeController()
        cfg = AutoscaleConfig(min_workers=1, max_workers=3, high_depth=4,
                              low_depth=1, interval_s=0.02, up_after=2,
                              down_after=3)
        scaler = Autoscaler(cluster, ctrl, cfg)
        ctrl.depth = 5
        scaler.start()
        try:
            deadline = time.perf_counter() + 2.0
            while cluster.n < 3 and time.perf_counter() < deadline:
                time.sleep(0.01)
            assert cluster.n == 3           # grew, and capped at max_workers
            time.sleep(0.1)
            assert cluster.n == 3           # never exceeds the bound
            assert ctrl.lanes == [1, 2]     # each new worker got a lane
            ctrl.depth = 0
            deadline = time.perf_counter() + 2.0
            while cluster.n > 1 and time.perf_counter() < deadline:
                time.sleep(0.01)
            assert cluster.n == 1           # shrank, and floored at min
            time.sleep(0.1)
            assert cluster.n == 1
            assert len(ctrl.closed) == 2
        finally:
            scaler.stop()

    def test_blip_below_hysteresis_does_not_scale(self):
        cluster, ctrl = _FakeCluster(), _FakeController()
        cfg = AutoscaleConfig(min_workers=1, max_workers=3, high_depth=4,
                              low_depth=1, interval_s=0.02, up_after=50,
                              down_after=50)
        scaler = Autoscaler(cluster, ctrl, cfg)
        ctrl.depth = 10
        scaler.start()
        try:
            time.sleep(0.15)                # far fewer than 50 intervals
            assert cluster.n == 1 and not cluster.ups
        finally:
            scaler.stop()

    def test_config_validation(self):
        with pytest.raises(ValueError):
            AutoscaleConfig(min_workers=0)
        with pytest.raises(ValueError):
            AutoscaleConfig(min_workers=3, max_workers=2)
        with pytest.raises(ValueError):
            AutoscaleConfig(low_depth=9, high_depth=8)
        with pytest.raises(ValueError):
            AutoscaleConfig(interval_s=0.0)


# ------------------------------------------- executor sizing (no models)

class TestExecutorSizing:
    def test_width_derives_from_admission_caps(self, tmp_path):
        c = Cluster(str(tmp_path / "a"), n_workers=4,
                    admission=AdmissionConfig(queue_depth=2,
                                              worker_concurrency=3))
        try:
            assert c._executor._max_workers == 4 * (3 + 2)
        finally:
            c.shutdown()

    def test_width_floor_and_explicit_cap(self, tmp_path):
        small = Cluster(str(tmp_path / "b"), n_workers=1)
        try:
            assert small._executor._max_workers == 8   # floor
        finally:
            small.shutdown()
        capped = Cluster(str(tmp_path / "c"), n_workers=4,
                         max_concurrency=5)
        try:
            assert capped._executor._max_workers == 5  # user cap wins
        finally:
            capped.shutdown()

    def test_resizes_with_the_fleet(self, tmp_path):
        adm = AdmissionConfig(queue_depth=2, worker_concurrency=3)
        c = Cluster(str(tmp_path / "d"), n_workers=2, admission=adm)
        try:
            assert c._executor._max_workers == max(8, 2 * 5)
            assert c.scale_up() is not None
            assert c._executor._max_workers == 3 * 5
            assert c.retire_worker(c.workers[-1].worker_id)
            assert c._executor._max_workers == max(8, 2 * 5)
        finally:
            c.shutdown()


# --------------------------------------------- cluster-level (real models)

@pytest.fixture(scope="module")
def sched_env(tmp_path_factory):
    from repro.configs import get_config, reduced
    from repro.core.snapshot import flatten_pytree
    from repro.models import build_model
    from repro.serving.trace import build_cluster
    import jax

    root = str(tmp_path_factory.mktemp("sched"))
    cfg = reduced(get_config("gemma-2b"))
    model = build_model(cfg)
    cluster, specs = build_cluster(
        root, cfg, model, n_workers=3, n_functions=4,
        placement="affinity", steal=StealConfig(min_depth=1,
                                                min_cold_depth=3),
    )
    base_flat = flatten_pytree(
        jax.tree.map(np.asarray, model.init(0)))
    yield SimpleNamespace(root=root, cfg=cfg, model=model, cluster=cluster,
                          specs=specs, base_flat=base_flat)
    cluster.shutdown()


def _req(spec, cfg, seed=0):
    from repro.serving.trace import request_tokens
    toks = request_tokens(spec, np.random.default_rng(seed), cfg.vocab_size)
    return InvocationRequest(function=spec.name, tokens=toks)


class TestClusterPlacement:
    def test_affinity_spreads_by_load(self, sched_env):
        cluster = sched_env.cluster
        homes = {s.name: cluster.worker_for(s.name).worker_id
                 for s in sched_env.specs}
        # 4 functions over 3 workers: nobody gets more than 2, nobody 0
        counts = {w.worker_id: 0 for w in cluster.workers}
        for wid in homes.values():
            counts[wid] += 1
        assert max(counts.values()) <= 2 and min(counts.values()) >= 1

    def test_identical_registration_is_deterministic(self, sched_env, tmp_path):
        from repro.serving.trace import build_cluster
        maps = []
        for tag in ("x", "y"):
            c, specs = build_cluster(
                str(tmp_path / tag), sched_env.cfg, sched_env.model,
                n_workers=3, n_functions=4, placement="affinity",
            )
            try:
                maps.append({s.name: c.worker_for(s.name).worker_id
                             for s in specs})
            finally:
                c.shutdown()
        assert maps[0] == maps[1]

    def test_delta_siblings_colocate(self, sched_env):
        from repro.serving.worker import FunctionSpec
        cluster, cfg = sched_env.cluster, sched_env.cfg
        sibs = []
        for i in range(2):
            table = np.array(sched_env.base_flat["embed/table"])
            table[i] += 0.01
            spec = FunctionSpec(name=f"sib{i}", family=cfg.name,
                                delta={"embed/table": table})
            cluster.register_function(spec)
            sibs.append(spec)
        try:
            homes = {cluster.worker_for(s.name).worker_id for s in sibs}
            assert len(homes) == 1          # chunk-sharing affinity won
        finally:
            for s in sibs:
                cluster.deregister_function(s.name)

    def test_home_is_sticky_across_invokes(self, sched_env):
        cluster, spec = sched_env.cluster, sched_env.specs[0]
        home = cluster.worker_for(spec.name).worker_id
        for seed in range(3):
            r = cluster.invoke(_req(spec, sched_env.cfg, seed=seed))
            assert r.worker_id == home

    def test_replacement_after_crash_and_failover(self, sched_env):
        cluster, spec = sched_env.cluster, sched_env.specs[1]
        old_home = cluster.worker_for(spec.name).worker_id
        # simulate a detected crash: the home leaves the candidate set
        with cluster._results_lock:
            cluster._dead.add(old_home)
        try:
            new_home = cluster.worker_for(spec.name).worker_id
            assert new_home != old_home
            # sticky again on the survivor, and requests complete there
            assert cluster.worker_for(spec.name).worker_id == new_home
            r = cluster.invoke(_req(spec, sched_env.cfg))
            assert r.worker_id == new_home
        finally:
            with cluster._results_lock:
                cluster._dead.discard(old_home)

    def test_runtime_shares_one_jitted_forward(self, sched_env):
        cluster, cfg = sched_env.cluster, sched_env.cfg
        fwds = {id(w._fwd[cfg.name]) for w in cluster.workers}
        assert len(fwds) == 1               # one compile fleet-wide
        new = cluster.scale_up()
        assert new is not None
        try:
            assert id(new._fwd[cfg.name]) in fwds
        finally:
            cluster.retire_worker(new.worker_id)


class TestStealGates:
    def test_warm_thief_steals_even_during_cold_flight(self, sched_env):
        cluster, spec = sched_env.cluster, sched_env.specs[0]
        cluster.invoke(_req(spec, sched_env.cfg))       # warm at home
        home = cluster.worker_for(spec.name).worker_id
        assert cluster.steal_ok(home, spec.name, 1)     # warm: any depth
        lock = cluster._acquire_flight(spec.name)
        try:
            # stolen warm requests ride the lock-free warm path, so an
            # in-flight cold start elsewhere must not block them
            assert cluster.steal_ok(home, spec.name, 5)
        finally:
            lock.release()

    def test_cold_thief_needs_depth_and_free_flight(self, sched_env):
        cluster, spec = sched_env.cluster, sched_env.specs[0]
        cluster.invoke(_req(spec, sched_env.cfg))
        home = cluster.worker_for(spec.name).worker_id
        thief = next(w.worker_id for w in cluster.workers
                     if w.worker_id != home)
        # make the breakeven unambiguous: long queues, cheap re-cold
        with cluster._results_lock:
            cluster._service_ema = 2.0
        with cluster._topology:
            cluster._fn_cost[spec.name] = 0.01
        cfg = cluster.steal
        assert not cluster.steal_ok(thief, spec.name,
                                    cfg.min_cold_depth - 1)
        assert cluster.steal_ok(thief, spec.name, cfg.min_cold_depth)
        lock = cluster._acquire_flight(spec.name)
        try:
            # a cold steal would serialise behind the in-flight boot
            assert not cluster.steal_ok(thief, spec.name,
                                        cfg.min_cold_depth)
        finally:
            lock.release()

    def test_no_steal_when_disabled_or_shallow(self, sched_env):
        cluster, spec = sched_env.cluster, sched_env.specs[0]
        home = cluster.worker_for(spec.name).worker_id
        assert not cluster.steal_ok(home, spec.name, 0)  # below min_depth
        saved, cluster.steal = cluster.steal, None
        try:
            assert not cluster.steal_ok(home, spec.name, 99)
        finally:
            cluster.steal = saved


class TestWarmFastPath:
    def test_warm_target_requires_residency(self, sched_env):
        cluster, spec = sched_env.cluster, sched_env.specs[2]
        req = _req(spec, sched_env.cfg)
        home = cluster.worker_for(spec.name)
        home.pool.drop(spec.name)
        assert cluster._warm_target(req, None) is None   # cold: locked path
        cluster.invoke(req)
        assert cluster._warm_target(req, None) is home   # warm: lock-free
        home.pool.drop(spec.name)
        assert cluster._warm_target(req, None) is None

    def test_warm_invokes_do_not_hold_the_flight_lock(self, sched_env):
        # a held single-flight lock must not serialise warm requests —
        # the cold-scoped single-flight property the stealing relies on
        cluster, spec = sched_env.cluster, sched_env.specs[2]
        cluster.invoke(_req(spec, sched_env.cfg))        # ensure warm
        lock = cluster._acquire_flight(spec.name)
        done = threading.Event()
        out = {}

        def _warm_invoke():
            out["r"] = cluster.invoke(_req(spec, sched_env.cfg, seed=7))
            done.set()

        t = threading.Thread(target=_warm_invoke)
        try:
            t.start()
            assert done.wait(timeout=30.0), \
                "warm request blocked behind the flight lock"
            assert not out["r"].cold
        finally:
            lock.release()
            t.join(timeout=10.0)
