"""Bounded concurrency soak (``-m soak``): N threads hammer register /
invoke / prefetch / demote / record / deregister against one cluster, with
byte-equivalence asserts on every invocation output.  The invoke mix
includes demand-paged cold starts (forced and AUTO-resolved) racing the
record ops that rewrite the working sets they prefetch from, and the
demote ops that move the chunks they lazily fault in.

This is the instrument that shook out the ISSUE 5 race fixes (plan-epoch
check-then-act, tier lookup-then-read vs demotion, deregister vs in-flight
cold start).  Fixed seed, bounded wall time (``REPRO_SOAK_SECONDS``,
default ~25 s of op time); acceptance is zero byte-equivalence violations
and zero lost invocations — every submitted op resolves to a correct
result or a *clean*, expected error (a request racing a deregistration
sees "not registered", never wrong bytes or a stuck future).
"""

import os
import threading
import time

import numpy as np
import pytest

from repro.core import TierSpec
from repro.serving import ColdStartOptions, InvocationRequest, Strategy

SOAK_SECONDS = float(os.environ.get("REPRO_SOAK_SECONDS", "25"))
N_THREADS = 6
SEED = 0xF1EE7

# fast remote throttle: movement semantics, not timing
FAST_REMOTE = dict(remote_bw=10e9, remote_lat=0.0)


@pytest.mark.soak
def test_concurrency_soak_byte_equivalence_and_conservation(tmp_path):
    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.serving.trace import build_cluster, request_tokens

    cfg = reduced(get_config("gemma-2b"))
    model = build_model(cfg)
    cluster, specs = build_cluster(
        str(tmp_path), cfg, model, n_workers=2, n_functions=4,
        tiers=TierSpec(ram_bytes=32 << 20, **FAST_REMOTE),
    )
    token_seeds = (11, 23, 47)

    with cluster:
        # ground truth: serial cold invocations, one per (function, seed)
        expected = {}
        for spec in specs:
            for s in token_seeds:
                toks = request_tokens(spec, np.random.default_rng(s),
                                      cfg.vocab_size)
                r = cluster.invoke(InvocationRequest(
                    function=spec.name, tokens=toks,
                    options=ColdStartOptions(force_cold=True),
                ))
                expected[(spec.name, s)] = np.asarray(r.output)

        # one registration guard per function: the op mix deregisters and
        # re-registers, and a test thread must never double-deregister
        reg_locks = {spec.name: threading.Lock() for spec in specs}
        counters = {
            "submitted": 0, "ok": 0, "invoke_clean": 0,
            "lifecycle_clean": 0, "mismatches": 0, "recorded": 0,
            "demand_paged": 0, "unexpected": [],
        }
        clock = time.perf_counter
        counters_lock = threading.Lock()
        deadline = clock() + SOAK_SECONDS
        stop = threading.Event()

        def bump(key, n=1):
            with counters_lock:
                counters[key] += n

        def is_clean(exc) -> bool:
            """Errors a racing lifecycle op is *allowed* to produce."""
            if isinstance(exc, KeyError):
                return "not registered" in str(exc) or \
                    any(spec.name in str(exc) for spec in specs)
            return False

        def run_ops(thread_idx: int):
            rng = np.random.default_rng(SEED + thread_idx)
            while not stop.is_set() and clock() < deadline:
                spec = specs[int(rng.integers(len(specs)))]
                dice = rng.random()
                try:
                    if dice < 0.66:                       # invoke
                        s = int(rng.choice(token_seeds))
                        toks = request_tokens(
                            spec, np.random.default_rng(s), cfg.vocab_size)
                        strategy = Strategy.AUTO if rng.random() < 0.25 \
                            else Strategy.SNAPFAAS
                        # a third of invokes force the demand-paged restore,
                        # racing concurrent record/demote/deregister ops
                        demand = bool(rng.random() < 0.33)
                        bump("submitted")
                        fut = cluster.submit(InvocationRequest(
                            function=spec.name, tokens=toks,
                            options=ColdStartOptions(
                                strategy=strategy,
                                force_cold=bool(rng.random() < 0.3),
                                demand_paging=True if demand else None),
                        ))
                        try:
                            r = fut.result(timeout=120)
                        except Exception as e:  # noqa: BLE001
                            bump("invoke_clean") if is_clean(e) else \
                                counters["unexpected"].append(e)
                            continue
                        if r.metrics is not None and r.metrics.demand_paged:
                            bump("demand_paged")
                        if np.array_equal(np.asarray(r.output),
                                          expected[(spec.name, s)]):
                            bump("ok")
                        else:
                            bump("mismatches")
                    elif dice < 0.76:                     # prefetch
                        cat = str(rng.choice(["ws", "diff", "ws_full"]))
                        cluster.prefetch_function(spec.name, cat)
                    elif dice < 0.86:                     # demote
                        cluster.worker_for(spec.name) \
                               .registry.demote_function(spec.name)
                    elif dice < 0.93:                     # record (REAP profile)
                        s = int(rng.choice(token_seeds))
                        toks = request_tokens(
                            spec, np.random.default_rng(s), cfg.vocab_size)
                        bump("submitted")
                        try:
                            r = cluster.record_function(spec.name, toks)
                        except Exception as e:  # noqa: BLE001
                            bump("invoke_clean") if is_clean(e) else \
                                counters["unexpected"].append(e)
                            continue
                        bump("recorded")
                        if np.array_equal(np.asarray(r.output),
                                          expected[(spec.name, s)]):
                            bump("ok")
                        else:
                            bump("mismatches")
                    else:                                 # deregister cycle
                        lock = reg_locks[spec.name]
                        if not lock.acquire(blocking=False):
                            continue
                        try:
                            cluster.deregister_function(spec.name)
                            cluster.register_function(spec)
                        finally:
                            lock.release()
                except Exception as e:  # noqa: BLE001
                    if is_clean(e):
                        bump("lifecycle_clean")
                    else:
                        counters["unexpected"].append(e)

        threads = [threading.Thread(target=run_ops, args=(i,))
                   for i in range(N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=SOAK_SECONDS + 300)
            assert not t.is_alive(), "soak thread hung (lost invocations)"
        stop.set()

        # zero byte-equivalence violations, zero lost invocations, no
        # unexpected failure modes
        assert counters["mismatches"] == 0, counters
        assert not counters["unexpected"], counters["unexpected"][:5]
        assert counters["ok"] > 0
        # the storm actually exercised the new paths: profiled recordings
        # were cut and demand-paged cold starts ran against them
        assert counters["recorded"] > 0, counters
        assert counters["demand_paged"] > 0, counters
        # every submitted invocation resolved: correct output, a clean
        # lifecycle-race error, or a (zero) mismatch — none lost
        assert counters["submitted"] == \
            counters["ok"] + counters["mismatches"] + counters["invoke_clean"]

        # the fleet still serves correctly after the storm: every function
        # cold-restores byte-identical to the serial ground truth
        for spec in specs:
            with reg_locks[spec.name]:
                if spec.name not in cluster.worker_for(spec.name).specs:
                    cluster.register_function(spec)
                s = token_seeds[0]
                toks = request_tokens(spec, np.random.default_rng(s),
                                      cfg.vocab_size)
                r = cluster.invoke(InvocationRequest(
                    function=spec.name, tokens=toks,
                    options=ColdStartOptions(force_cold=True),
                ))
                np.testing.assert_array_equal(
                    np.asarray(r.output), expected[(spec.name, s)],
                    err_msg=spec.name,
                )

        m = cluster.metrics()
        assert m["serving"]["n_samples"] > 0
        assert m["serving"]["n_shed"] == 0     # no admission layer here
