"""Tiered chunk storage: flat-store equivalence, promotion/demotion
semantics, tier-aware planning, and the serving-layer prefetch path."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AccessLog,
    ChunkStore,
    TieredChunkStore,
    TierSpec,
    ZygoteRegistry,
    flatten_pytree,
)
from repro.core.planner import (
    StorageModel,
    TieredStorageModel,
    TierModel,
    predict,
)
from repro.core.tiers import RamCacheTier, TierReadStats

CHUNK = 4096

# fast remote throttle for tests: semantics, not timing
FAST_REMOTE = dict(remote_bw=10e9, remote_lat=0.0)


def _payloads(rng, n, max_size=9000, nzero=2):
    out = []
    for i in range(n):
        size = int(rng.integers(1, max_size))
        if i < nzero:
            out.append(b"\x00" * size)
        else:
            out.append(rng.integers(0, 255, size, dtype=np.uint8).tobytes())
    return out


def _fill(store, payloads, pack_id="p0"):
    pack = store.open_pack(pack_id)
    refs = store.put_chunks(pack, payloads)
    pack.close()
    store.save_index()
    return refs


# ------------------------------------------------------------- RAM cache tier

class TestRamCacheTier:
    def test_lru_eviction_bounded(self):
        tier = RamCacheTier(capacity_bytes=10)
        assert tier.put("a", b"xxxx") and tier.put("b", b"yyyy")
        assert tier.put("c", b"zzzz")  # evicts "a" (LRU)
        assert tier.used <= 10
        assert tier.get("a") is None
        assert tier.get("b") == b"yyyy"
        assert tier.evictions == 1

    def test_oversized_payload_refused(self):
        tier = RamCacheTier(capacity_bytes=4)
        assert not tier.put("big", b"12345")
        assert tier.used == 0

    def test_access_refreshes_lru_order(self):
        tier = RamCacheTier(capacity_bytes=8)
        tier.put("a", b"1111")
        tier.put("b", b"2222")
        tier.get("a")  # now "b" is LRU
        tier.put("c", b"3333")
        assert tier.get("b") is None
        assert tier.get("a") == b"1111"


# ---------------------------------------------------- flat-store equivalence

class TestTieredEquivalence:
    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2 ** 16),
        ram_bytes=st.sampled_from([0, 1, 6000, 12000, 1 << 20]),
        n_demote=st.integers(0, 12),
        promote=st.booleans(),
    )
    def test_read_batch_into_matches_flat_store(
        self, tmp_path_factory, seed, ram_bytes, n_demote, promote
    ):
        """INVARIANT: whatever the cache capacity, eviction pressure, or
        remote residency, the tiered scatter-read returns byte-identical
        content to a flat ChunkStore holding the same payloads."""
        tmp = tmp_path_factory.mktemp("eq")
        rng = np.random.default_rng(seed)
        payloads = _payloads(rng, 12)

        flat = ChunkStore(str(tmp / "flat"))
        refs = _fill(flat, payloads)
        tiered = TieredChunkStore(
            str(tmp / "tiered"),
            spec=TierSpec(ram_bytes=ram_bytes, **FAST_REMOTE),
        )
        refs2 = _fill(tiered, payloads)
        assert [r.digest for r in refs] == [r.digest for r in refs2]
        # scatter residency: demote a random subset to the remote tier
        order = rng.permutation(len(refs))[:n_demote]
        tiered.demote([refs[i] for i in order])

        # duplicate some refs so the dedupe/replicate path is exercised
        req = list(refs) + [refs[int(rng.integers(0, len(refs)))]]
        expect = {}
        bufs_flat = [bytearray(r.size) for r in req]
        flat.read_batch_into([(r, memoryview(b)) for r, b in zip(req, bufs_flat)])
        bufs_tier = [bytearray(r.size) for r in req]
        stats = TierReadStats()
        tiered.read_batch_into(
            [(r, memoryview(b)) for r, b in zip(req, bufs_tier)],
            stats=stats, promote=promote,
        )
        for r, bf, bt in zip(req, bufs_flat, bufs_tier):
            assert bytes(bf) == bytes(bt), r.digest
        tiered.join_promotions()
        # and again after promotion settled (chunks may have moved tiers)
        bufs_tier2 = [bytearray(r.size) for r in req]
        tiered.read_batch_into(
            [(r, memoryview(b)) for r, b in zip(req, bufs_tier2)]
        )
        for bf, bt in zip(bufs_flat, bufs_tier2):
            assert bytes(bf) == bytes(bt)
        flat.close()
        tiered.close()

    def test_parallel_ram_copy_path_byte_identical(self, tmp_path):
        """RAM reads above _RAM_PARALLEL_BYTES fan ctypes.memmove across
        the I/O pool — content must match the serial path exactly."""
        rng = np.random.default_rng(7)
        cb = 256 * 1024
        payloads = [rng.integers(0, 255, cb, dtype=np.uint8).tobytes()
                    for _ in range(40)]  # 10 MiB: well past the threshold
        store = TieredChunkStore(
            str(tmp_path / "s"), spec=TierSpec(ram_bytes=1 << 30)
        )
        refs = _fill(store, payloads)
        store.prefetch(refs)
        buf = np.zeros(len(payloads) * cb, dtype=np.uint8)
        mv = memoryview(buf)
        dests = [(r, mv[i * cb:(i + 1) * cb]) for i, r in enumerate(refs)]
        stats = TierReadStats()
        n = store.read_batch_into(dests, stats=stats)
        assert n == len(payloads) * cb
        assert stats.tier_bytes == {"ram": n}
        for i, p in enumerate(payloads):
            assert bytes(mv[i * cb:(i + 1) * cb]) == p
        # serial path agrees
        buf2 = np.zeros_like(buf)
        mv2 = memoryview(buf2)
        store.read_batch_into(
            [(r, mv2[i * cb:(i + 1) * cb]) for i, r in enumerate(refs)],
            parallel=False,
        )
        assert bytes(mv) == bytes(mv2)
        store.close()

    def test_get_chunk_and_read_batch_tier_aware(self, tmp_path):
        rng = np.random.default_rng(0)
        payloads = _payloads(rng, 8)
        store = TieredChunkStore(
            str(tmp_path / "s"), spec=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE)
        )
        refs = _fill(store, payloads)
        store.demote(refs[4:])
        for r, p in zip(refs, payloads):
            assert store.get_chunk(r) == p
        batch = store.read_batch(refs)
        for r, p in zip(refs, payloads):
            if r.zero:
                assert r.digest not in batch
            else:
                assert batch[r.digest] == p


# ----------------------------------------------------- promotion / demotion

class TestTierMovement:
    def test_demote_then_fetch_promotes_downward(self, tmp_path):
        rng = np.random.default_rng(1)
        payloads = _payloads(rng, 6, nzero=0)
        store = TieredChunkStore(
            str(tmp_path / "s"), spec=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE)
        )
        refs = _fill(store, payloads)
        moved = store.demote(refs)
        assert moved == sum(len(p) for p in payloads)
        assert all(store.tier_of(r.digest) == "remote" for r in refs)
        epoch0 = store.residency_epoch

        bufs = [bytearray(r.size) for r in refs]
        stats = TierReadStats()
        store.read_batch_into(
            [(r, memoryview(b)) for r, b in zip(refs, bufs)], stats=stats
        )
        store.join_promotions()
        assert stats.tier_bytes.get("remote") == moved
        assert store.residency_epoch > epoch0
        # promoted: now resident warm (ram first, local behind it)
        assert all(store.tier_of(r.digest) == "ram" for r in refs)
        assert store.promoted_bytes == moved
        # a second read is served entirely from the warm tiers
        stats2 = TierReadStats()
        bufs2 = [bytearray(r.size) for r in refs]
        store.read_batch_into(
            [(r, memoryview(b)) for r, b in zip(refs, bufs2)], stats=stats2
        )
        assert "remote" not in stats2.tier_bytes
        assert bufs == bufs2

    def test_promote_false_pins_chunks_remote(self, tmp_path):
        rng = np.random.default_rng(2)
        payloads = _payloads(rng, 4, nzero=0)
        store = TieredChunkStore(
            str(tmp_path / "s"), spec=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE)
        )
        refs = _fill(store, payloads)
        store.demote(refs)
        bufs = [bytearray(r.size) for r in refs]
        store.read_batch_into(
            [(r, memoryview(b)) for r, b in zip(refs, bufs)], promote=False
        )
        store.join_promotions()
        assert all(store.tier_of(r.digest) == "remote" for r in refs)
        assert store.promoted_bytes == 0

    def test_prefetch_with_ram_disabled_is_counted_noop(self, tmp_path):
        """With no RAM tier, local chunks are already as warm as the
        hierarchy gets: prefetch must not read, count, or bump the epoch."""
        rng = np.random.default_rng(5)
        store = TieredChunkStore(str(tmp_path / "s"),
                                 spec=TierSpec(ram_bytes=0))
        refs = _fill(store, _payloads(rng, 5, nzero=0))
        epoch = store.residency_epoch
        stats = store.prefetch(refs)
        assert stats.prefetched_chunks == 0
        assert stats.prefetched_bytes == 0
        assert store.residency_epoch == epoch

    def test_accounting_is_union_across_pack_tiers(self, tmp_path):
        """Demotion moves bytes, promotion copies them — logical
        stored_bytes/num_chunks must stay constant through both."""
        rng = np.random.default_rng(6)
        store = TieredChunkStore(
            str(tmp_path / "s"), spec=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE)
        )
        refs = _fill(store, _payloads(rng, 5, nzero=0))
        before, n = store.stored_bytes(), store.num_chunks
        store.demote(refs[:2])
        assert store.location(refs[0].digest) is not None  # remote-resident
        assert (store.stored_bytes(), store.num_chunks) == (before, n)
        bufs = [bytearray(r.size) for r in refs[:2]]
        store.read_batch_into(
            [(r, memoryview(b)) for r, b in zip(refs[:2], bufs)]
        )
        store.join_promotions()  # now resident in both pack tiers
        assert (store.stored_bytes(), store.num_chunks) == (before, n)

    def test_prefetch_lifts_ws_into_warm_tiers(self, tmp_path):
        rng = np.random.default_rng(3)
        payloads = _payloads(rng, 6, nzero=0)
        store = TieredChunkStore(
            str(tmp_path / "s"), spec=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE)
        )
        refs = _fill(store, payloads)
        store.demote(refs[:3])
        stats = store.prefetch(refs)
        assert stats.remote_bytes == sum(r.size for r in refs[:3])
        assert stats.prefetched_chunks == len(refs)
        assert all(store.tier_of(r.digest) == "ram" for r in refs)
        # idempotent: everything already warm
        again = store.prefetch(refs)
        assert again.prefetched_chunks == 0
        assert again.already_warm == len(refs)


# ------------------------------------------------------- registry integration

def _tree(seed=0, n=3, rows=128, cols=32):
    rng = np.random.default_rng(seed)
    return {
        f"layer{i}": {
            "w": rng.standard_normal((rows, cols)).astype(np.float32),
            "b": rng.standard_normal((cols,)).astype(np.float32),
        }
        for i in range(n)
    }


def _registry(tmp_path, *, tiers=None):
    reg = ZygoteRegistry(str(tmp_path / "reg"), chunk_bytes=CHUNK, tiers=tiers)
    base_tree = _tree(seed=0)
    reg.register_runtime("fam", base_tree)
    variant = _tree(seed=0)
    variant["layer2"]["w"] = variant["layer2"]["w"] + 0.5
    variant["layer1"]["w"][:8] = 0.0
    variant["head"] = {"w": np.full((16, 16), 2.0, np.float32)}
    reg.register_function("fn", "fam", variant)
    log = AccessLog()
    for p in ("layer0/w", "layer0/b", "layer1/w", "layer2/w", "head/w"):
        log.touch(p)
    reg.generate_working_set("fn", log)
    return reg, variant


class TestRegistryTiered:
    def test_all_strategies_byte_identical_with_remote_residency(self, tmp_path):
        """Acceptance: the tiered store restores byte-identically across all
        five strategies even when the function's chunks live remote."""
        reg, variant = _registry(
            tmp_path, tiers=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE)
        )
        moved = reg.demote_function("fn")
        assert moved > 0
        flat = flatten_pytree(variant)
        src = lambda: {p: np.array(a) for p, a in flat.items()}
        kw = {
            "snapfaas": {},
            "snapfaas-": {},
            "reap": {},
            "seuss": dict(source_loader=src),
            "regular": dict(source_loader=src, base_loader=src),
        }
        for strategy, extra in kw.items():
            inst = reg.cold_start("fn", strategy, **extra)
            for path, expected in flat.items():
                np.testing.assert_array_equal(
                    inst.value(path), expected, err_msg=f"{strategy}/{path}"
                )
            reg.store.join_promotions()

    def test_promotion_never_double_counts_eager_bytes(self, tmp_path):
        """Acceptance: eager_bytes is the plan's eager set, restore after
        restore — promotion changes which tier serves it, never the count;
        the per-tier split always sums to it."""
        reg, _ = _registry(
            tmp_path, tiers=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE)
        )
        reg.demote_function("fn")
        counts = []
        for _ in range(3):
            inst = reg.cold_start("fn", "snapfaas")
            m = inst.metrics
            assert sum(m.tier_bytes.values()) == m.eager_bytes
            counts.append((m.eager_bytes, m.eager_chunks))
            reg.store.join_promotions()
        assert len(set(counts)) == 1  # identical across promotions
        # by now promotion has drained the remote tier: served warm
        warm = reg.cold_start("fn", "snapfaas").metrics
        assert "remote" not in warm.tier_bytes

    def test_plan_split_refreshed_on_residency_change(self, tmp_path):
        """Tier movement refreshes a cached plan's placement in place —
        classification is residency-independent, so the plan itself (the
        expensive part) is never rebuilt."""
        reg, _ = _registry(
            tmp_path, tiers=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE)
        )
        plan1 = reg.restore_plan("fn", "snapfaas")
        assert set(plan1.tier_split) == {"local"} or "ram" in plan1.tier_split
        arrays1 = plan1.arrays
        reg.demote_function("fn")
        plan2 = reg.restore_plan("fn", "snapfaas")
        assert plan2 is plan1 and plan2.arrays is arrays1  # not rebuilt
        assert "remote" in plan2.tier_split                # but re-placed
        assert plan2.residency_epoch == reg.store.residency_epoch

    def test_sizes_reports_tier_splits(self, tmp_path):
        reg, _ = _registry(
            tmp_path, tiers=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE)
        )
        sizes = reg.sizes("fn")
        assert set(sizes.tier_splits) == {"full", "diff", "ws", "ws_full"}
        assert sum(sizes.tier_splits["ws"].values()) == sizes.ws_bytes
        reg.demote_function("fn")
        sizes2 = reg.sizes("fn")
        assert sizes2.tier_splits["ws"].get("remote", 0) > 0


# -------------------------------------------------------------- planner model

class TestTieredPlannerModel:
    HW = TieredStorageModel(
        name="t", bw_store=1e9, lat_store=1e-4,
        bw_mem=50e9, lat_mem=1e-7, bw_dma=30e9, preconfig=1e-3,
        tiers=(
            TierModel(name="ram", bw_store=50e9, lat_store=1e-6),
            TierModel(name="local", bw_store=1e9, lat_store=1e-4),
            TierModel(name="remote", bw_store=100e6, lat_store=5e-3),
        ),
    )

    def test_eager_time_is_max_of_pipelined_streams(self):
        split = {"ram": 10 << 20, "local": 10 << 20, "remote": 10 << 20}
        t = self.HW.eager_time(30 << 20, split=split)
        # pipelined: the remote stream dominates, the others hide under it
        remote_only = 5e-3 + (10 << 20) / 100e6
        assert t == pytest.approx(remote_only)

    def test_unsplit_bytes_fall_back_to_flat_constants(self):
        t = self.HW.eager_time(10 << 20, split={"ram": 1 << 20})
        flat = 1e-4 + (9 << 20) / 1e9
        assert t == pytest.approx(max(flat, 1e-6 + (1 << 20) / 50e9))

    def test_no_split_matches_flat_model(self):
        flat = StorageModel(
            name="f", bw_store=1e9, lat_store=1e-4,
            bw_mem=50e9, lat_mem=1e-7, bw_dma=30e9, preconfig=1e-3,
        )
        assert self.HW.eager_time(123456) == flat.eager_time(123456)

    def test_predict_prices_residency(self, tmp_path):
        """The same function predicts a slower B when its working set is
        remote-resident than when it is warm — Eq. 1 from the actual split."""
        reg, _ = _registry(
            tmp_path, tiers=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE)
        )
        warm = predict("snapfaas", reg.sizes("fn"), self.HW)
        reg.demote_function("fn")
        cold = predict("snapfaas", reg.sizes("fn"), self.HW)
        assert cold.B > warm.B
        assert cold.total > warm.total


# ------------------------------------------------------------- serving layer

class TestServingTiers:
    @pytest.fixture()
    def worker(self, tmp_path):
        jax = pytest.importorskip("jax")
        from repro.models import build_model
        from repro.models.config import ModelConfig
        from repro.serving.worker import FunctionSpec, Worker

        cfg = ModelConfig(
            name="t", family="dense", num_layers=2, d_model=64, num_heads=2,
            num_kv_heads=2, d_ff=128, vocab_size=256, tie_embeddings=True,
            dtype="float32",
        )
        model = build_model(cfg)
        worker = Worker(
            str(tmp_path / "w"), chunk_bytes=4096,
            tiers=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE),
        )
        base_params = model.init(0)
        worker.register_runtime("t", model, base_params)
        flat = flatten_pytree(jax.tree.map(np.asarray, base_params))
        variant = {k: np.array(v) for k, v in flat.items()}
        for k in variant:
            if k.endswith("wq"):
                variant[k] = variant[k] + 0.01
        worker.register_function(FunctionSpec(name="fn", family="t",
                                              variant=variant))
        return worker

    def test_register_prefetches_working_set(self, worker):
        stats = worker.tier_stats()
        assert stats["prefetched_bytes"] > 0
        assert stats["ram"]["used_bytes"] > 0

    def test_prefetch_hint_and_invoke(self, worker):
        import numpy as np

        from repro.serving import ColdStartOptions, InvocationRequest, Strategy

        worker.registry.demote_function("fn")
        worker.registry.store.drop_page_cache()  # clears the RAM tier too
        toks = np.zeros((1, 4), np.int32)
        r = worker.invoke(InvocationRequest(
            function="fn", tokens=toks,
            options=ColdStartOptions(strategy=Strategy.SNAPFAAS,
                                     force_cold=True, prefetch=True),
        ))
        assert r.cold
        # the prefetch hint promoted the WS before the timed boot: the
        # eager read never touched the remote tier
        assert "remote" not in r.metrics.tier_bytes
        assert worker.tier_stats()["prefetched_bytes"] > 0

    def test_cluster_metrics_expose_tier_outcomes(self, tmp_path):
        jax = pytest.importorskip("jax")
        from repro.models import build_model
        from repro.models.config import ModelConfig
        from repro.serving.cluster import Cluster
        from repro.serving.worker import FunctionSpec

        cfg = ModelConfig(
            name="t", family="dense", num_layers=2, d_model=64, num_heads=2,
            num_kv_heads=2, d_ff=128, vocab_size=256, tie_embeddings=True,
            dtype="float32",
        )
        model = build_model(cfg)
        with Cluster(
            str(tmp_path / "c"), n_workers=1, chunk_bytes=4096,
            tiers=TierSpec(ram_bytes=1 << 20, **FAST_REMOTE),
        ) as cluster:
            base_params = model.init(0)
            cluster.register_runtime("t", model, base_params)
            flat = flatten_pytree(jax.tree.map(np.asarray, base_params))
            variant = {k: np.array(v) for k, v in flat.items()}
            variant["embed/table"] = variant["embed/table"] + 0.01
            cluster.register_function(FunctionSpec(name="fn", family="t",
                                                   variant=variant))
            m = cluster.metrics()
        tiers = m["tiers"]
        for key in ("ram_hits", "promoted_bytes", "prefetched_bytes",
                    "remote_fetch_s", "remote_fetched_bytes",
                    "prefetch_fetch_s"):
            assert key in tiers, key
        assert tiers["prefetched_bytes"] > 0
        assert m["per_worker"][0]["tiers"]["ram"]["used_bytes"] > 0
